"""End-to-end serving driver: batched requests through the scheduler with a
GEAR 4-bit cache, compared against the FP16 cache (logit fidelity + size),
served with slot-level continuous batching (wave mode: ``sched.run()``).

    PYTHONPATH=src python examples/serve_compressed.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Request, Scheduler


def main():
    cfg = smoke_config("llama2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)

    results = {}
    for name, policy in (("fp16", FP16), ("gear-4bit", pol)):
        eng = Engine(model, params, EngineConfig(batch=2, capacity=128, policy=policy))
        sched = Scheduler(eng, prompt_pad=32)
        for rid in range(4):
            sched.submit(Request(rid=rid,
                                 tokens=np.arange(20 + rid) % cfg.vocab_size,
                                 max_new_tokens=8 * (rid + 1)))   # mixed budgets
        out = sched.run_continuous()
        results[name] = {r.rid: r.tokens for r in out}
        assert sorted(results[name]) == list(range(4))
        caches = eng.init_caches()
        print(f"{name:10s} served {len(out)} requests, "
              f"cache alloc {eng.cache_nbytes(caches)/1e6:.2f} MB")

    agree = np.mean([
        (results["fp16"][rid][:8] == results["gear-4bit"][rid][:8]).mean()
        for rid in results["fp16"]])
    print(f"token agreement GEAR-4bit vs FP16: {100*agree:.1f}%")


if __name__ == "__main__":
    main()
