"""End-to-end serving driver: batched requests through the scheduler with a
GEAR 4-bit cache, served with slot-level continuous batching and the
radix-trie prefix cache — N requests of *different* raw lengths share one
long system prompt, so every request after the first splices the prompt's
compressed chunks from the trie and streams only its own (length-bucketed)
suffix.  No prompt padding anywhere: the scheduler hands raw token lists
to the engine, which buckets them internally (docs/serving.md).

Prints per-request prefill latency with the prefix cache on vs off, the
trie hit rate, and the GEAR-vs-FP16 logit fidelity check.

    PYTHONPATH=src python examples/serve_compressed.py [--smoke]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Request, Scheduler

N_REQUESTS = 6
SYSTEM_PROMPT_LEN = 48      # 3 chunks (n_b = 16) shared by every request


def requests(vocab: int, n: int, seed: int = 0) -> list[Request]:
    """Shared system prompt + per-request user suffixes of different raw
    lengths (deliberately not chunk-aligned — the mixed-length workload)."""
    rng = np.random.RandomState(seed)
    system = rng.randint(4, vocab, size=SYSTEM_PROMPT_LEN)
    return [Request(rid=rid,
                    tokens=np.concatenate(
                        [system,
                         rng.randint(4, vocab, size=rng.randint(5, 21))]),
                    max_new_tokens=8)
            for rid in range(n)]


def serve(model, params, policy, prefix_cache: bool, n: int):
    eng = Engine(model, params,
                 EngineConfig(batch=2, capacity=128, policy=policy,
                              prefill_mode="streaming",
                              prefix_cache=prefix_cache))
    sched = Scheduler(eng)
    for r in requests(model.cfg.vocab_size, n):
        sched.submit(r)
    out = sched.run_continuous()
    return eng, sched, {r.rid: r for r in out}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests, skip the FP16 fidelity pass (CI)")
    args = ap.parse_args()
    n_req = 4 if args.smoke else N_REQUESTS

    cfg = smoke_config("llama2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)

    results = {}
    for name, prefix_cache in (("cache-off", False), ("cache-on", True)):
        eng, sched, res = serve(model, params, pol, prefix_cache, n_req)
        results[name] = res
        # first request is always a cold miss; later ones splice the shared
        # system prompt, so steady-state prefill latency is what matters
        warm = [res[rid].prefill_s for rid in sorted(res)[1:]]
        line = (f"{name:10s} served {len(res)} requests, "
                f"steady-state prefill {1e3 * float(np.median(warm)):6.1f} ms"
                f" (first request {1e3 * res[min(res)].prefill_s:6.1f} ms)")
        if prefix_cache:
            line += (f", prefix_hit_rate {sched.last_stats['prefix_hit_rate']:.2f}"
                     f", prefill_toks_saved {sched.last_stats['prefill_toks_saved']}")
            # mixed raw lengths MUST still hit: the trie keys on raw
            # n_b-aligned chunks, so the shared system prompt matches no
            # matter how long each request's suffix is
            assert sched.last_stats["prefix_hit_rate"] > 0, sched.last_stats
            assert sched.last_stats["prefill_toks_saved"] > 0
        print(line)

    # the prefix cache is lossless: identical greedy tokens with it on/off
    assert all(np.array_equal(results["cache-off"][rid].tokens,
                              results["cache-on"][rid].tokens)
               for rid in results["cache-off"])
    print("prefix cache lossless: greedy tokens identical with cache on/off")
    if args.smoke:
        return

    # GEAR-vs-FP16 fidelity on the same workload (fp16 has no compressed
    # chunks, so it serves without the prefix cache)
    _, _, fp16 = serve(model, params, FP16, prefix_cache=False, n=n_req)
    agree = np.mean([
        (results["cache-on"][rid].tokens[:8] == fp16[rid].tokens[:8]).mean()
        for rid in fp16])
    print(f"token agreement GEAR-4bit vs FP16: {100 * agree:.1f}%")


if __name__ == "__main__":
    main()
