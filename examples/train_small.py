"""Train a ~small LM for a few hundred steps on the synthetic pipeline with
checkpoint/restart — the end-to-end training driver.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import tempfile

import jax

from repro.configs import smoke_config
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_test_mesh
from repro.models.model import build_model
from repro.train.loop import train_loop
from repro.train.state import RunConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = smoke_config("llama2-7b")
    model = build_model(cfg)
    run = RunConfig(total_steps=args.steps, warmup_steps=20, microbatches=2,
                    remat=True, remat_policy="dots", zero1=True,
                    ckpt_dir=tempfile.mkdtemp(prefix="repro_train_"),
                    ckpt_every=max(50, args.steps // 4), log_every=20)
    dc = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    state = train_loop(model, make_test_mesh(1, 1), run, dc)
    print(f"finished at step {int(state.step)}; checkpoints in {run.ckpt_dir}")


if __name__ == "__main__":
    main()
