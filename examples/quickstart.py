"""Quickstart: compress a KV matrix with GEAR and inspect the error/size.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (approx_error, compress_matrix, decompress_matrix,
                        kv_size_fraction, named_policy)


def main():
    key = jax.random.PRNGKey(0)
    # a KV-like tensor: [heads, tokens, head_dim] with a few outliers
    x = jax.random.normal(key, (8, 1024, 128))
    x = x * (1 + 6 * jax.random.bernoulli(key, 0.01, x.shape))

    for name in ("kivi2", "gear_l_kivi2", "gear_kivi2", "gear_kcvt4"):
        pol = named_policy(name)
        err = float(approx_error(x, pol, "k"))
        frac = kv_size_fraction(pol, 1024, 128, num_heads=1, head_dim=128)
        print(f"{name:14s} rel_error={err:.4f}  size={100*frac:.1f}% of FP16")

    # round-trip one matrix through the full GEAR decomposition
    cm = compress_matrix(x, named_policy("gear_kcvt4"), "k")
    xh = decompress_matrix(cm)
    print("\nGEAR 4-bit reconstruction:",
          f"max_abs_err={float(jnp.abs(x - xh).max()):.3f},",
          f"bytes={cm.size_bytes()} vs fp16 {x.size * 2}")


if __name__ == "__main__":
    main()
