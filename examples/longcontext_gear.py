"""Long-context serving with GEAR: grow a cache past what FP16 would allow
under the same byte budget, and watch compression events stream.

    PYTHONPATH=src python examples/longcontext_gear.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.policy import FP16, named_policy
from repro.core import metrics
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig


def main():
    cfg = smoke_config("gemma3-12b")  # local:global pattern — window + GEAR caches
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pol = dataclasses.replace(named_policy("gear_kivi2"), buffer_size=16, group=16)

    eng = Engine(model, params, EngineConfig(batch=1, capacity=512, policy=pol))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                                          cfg.vocab_size)}
    toks, stats = eng.generate(batch, 128)
    print(f"generated {toks.shape[1]} tokens; cache {stats['cache_bytes']/1e6:.2f} MB")

    frac = metrics.kv_size_fraction(pol, 512, cfg.num_kv_heads * cfg.head_dim,
                                    num_heads=cfg.num_kv_heads, head_dim=cfg.head_dim)
    print(f"analytic compressed size: {100*frac:.1f}% of FP16 "
          f"→ {1/frac:.1f}× longer context at equal HBM")


if __name__ == "__main__":
    main()
