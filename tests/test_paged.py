"""Paged compressed KV pool: paged ≡ dense bit-parity, allocator refcount
invariants, and pool-bytes-limited admission.

The archetype test is layout parity: a paged engine must produce caches,
logits, and greedy tokens bit-identical to the dense engine for the same
requests — across quant-only / low-rank / outlier GEAR policies and mixed
(windowed) layer trees.  This pins the zero-page invariant, the block-table
gather paths (kernel and oracle), the admission splice (zero + scatter +
row write), and refcounted prefix-page sharing all at once.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.models.transformer import cache_cfg_for
from repro.serving import (AttendPath, CacheLayout, CacheView, DenseCacheView,
                           Engine, EngineConfig, PagedCacheView, PagePool,
                           PoolExhausted, PrefillMode, Request, Scheduler,
                           pages_needed)
from repro.serving.scheduler import _pad

EOS = 3
PROMPT_PAD = 8
CAP = 48

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                   vocab_size=64)
TINY_WIN = dataclasses.replace(TINY, attn_pattern="local_global",
                               pattern_locals=1, local_window=8)


def _small(name):
    pol = named_policy(name)
    return dataclasses.replace(pol, buffer_size=8, group=min(pol.group, 8),
                               rank=2, rank_decode=2)


_MODELS: dict = {}


def _model(cfg):
    key = cfg.name + cfg.attn_pattern
    if key not in _MODELS:
        m = build_model(cfg)
        _MODELS[key] = (m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[key]


def _requests(n=5, seed=0):
    rng = np.random.RandomState(seed)
    budgets = [6, 3, 9, 1, 5, 7, 2][:n]
    return [Request(rid=i,
                    tokens=rng.randint(4, 64, size=rng.randint(2, PROMPT_PAD + 1)),
                    max_new_tokens=b)
            for i, b in enumerate(budgets)]


def _run(engine):
    sched = Scheduler(engine)
    for r in _requests():
        sched.submit(r)
    return {r.rid: r.tokens for r in sched.run_continuous()}, sched.last_stats


# ---------------------------------------------------------------------------
# Tentpole: paged ≡ dense (tokens, logits, caches)


@pytest.mark.parametrize("polname", ["gear_kcvt4", "kivi2", "gear_l_kivi2"])
def test_paged_matches_dense_tokens(polname):
    """Same requests through continuous batching: greedy tokens bit-equal
    across gear (lowrank+outlier), quant-only, and lowrank-only policies."""
    model, params = _model(TINY)
    pol = _small(polname)
    ecfg = EngineConfig(batch=3, capacity=CAP, policy=pol, eos_id=EOS)
    dense, _ = _run(Engine(model, params, ecfg))
    eng_p = Engine(model, params, dataclasses.replace(ecfg, layout="paged"))
    paged, stats = _run(eng_p)
    assert dense.keys() == paged.keys()
    for rid in dense:
        np.testing.assert_array_equal(dense[rid], paged[rid], err_msg=str(rid))
    eng_p.pool.check()
    assert stats["layout"] == "paged" and stats["pool"]["admits"] == 5


def test_paged_matches_dense_windowed_tree():
    """Mixed tree: window layers stay dense inside a paged engine and the
    whole model still matches the dense engine bit-for-bit."""
    model, params = _model(TINY_WIN)
    ecfg = EngineConfig(batch=2, capacity=CAP, policy=_small("gear_kcvt4"),
                        eos_id=EOS)
    dense, _ = _run(Engine(model, params, ecfg))
    eng_p = Engine(model, params, dataclasses.replace(ecfg, layout="paged"))
    paged, _ = _run(eng_p)
    for rid in dense:
        np.testing.assert_array_equal(dense[rid], paged[rid], err_msg=str(rid))


def test_paged_cache_and_logits_bitwise():
    """Slot prefill + decode steps: per-step logits and the slot's gathered
    cache row are bitwise equal to the dense layout's."""
    model, params = _model(TINY)
    pol = _small("gear_kcvt4")
    ecfg = EngineConfig(batch=3, capacity=CAP, policy=pol)
    eng_d = Engine(model, params, ecfg)
    eng_p = Engine(model, params, dataclasses.replace(ecfg, layout="paged"))
    cd, cp = eng_d.init_caches(), eng_p.init_caches()
    prompt = _pad(_requests()[0].tokens, PROMPT_PAD)[None]
    b1 = {"tokens": jnp.asarray(prompt, jnp.int32)}
    ld, cd = eng_d.prefill_slot(b1, cd, 1)
    lp, cp = eng_p.prefill_slot(b1, cp, 1, reserve_tokens=PROMPT_PAD + 20)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
    tok = jnp.asarray([[5], [7], [9]], jnp.int32)
    pos = jnp.asarray([0, PROMPT_PAD, 0], jnp.int32)
    for step in range(12):            # crosses a chunk boundary (n_b = 8)
        ld, cd = eng_d.decode({"tokens": tok}, cd, pos + step)
        lp, cp = eng_p.decode({"tokens": tok}, cp, pos + step)
        np.testing.assert_array_equal(np.asarray(ld[1]), np.asarray(lp[1]),
                                      err_msg=f"step {step}")
    ccfg = cache_cfg_for(TINY, "global", pol, 3, CAP)
    bt = jnp.asarray(eng_p.pool.block_tables)
    for i in range(len(cd)):
        for r in range(TINY.pattern_repeats):
            dl = jax.tree.map(lambda t: t[r], cd[i])
            dn = cache_lib.paged_to_dense(
                ccfg, jax.tree.map(lambda t: t[r], cp[i]), bt)
            for f in cache_lib._POOLED_FIELDS + ("buf_k", "buf_v", "length"):
                a = getattr(dl, f)
                if a is None:
                    assert getattr(dn, f) is None
                    continue
                # only the live slot's row is comparable: idle DENSE rows
                # accumulate garbage appends the paged layout drops by design
                np.testing.assert_array_equal(
                    np.asarray(a)[1], np.asarray(getattr(dn, f))[1],
                    err_msg=f"pos{i} r{r} {f}")


def test_paged_prefix_cache_shares_pages():
    """Shared-system-prompt workload: warm paged engine matches the cold
    dense engine bit-for-bit AND serves hits by page refcount (COW never
    copies — shared_pages > 0, zero payload bytes duplicated)."""
    model, params = _model(TINY)
    pol = _small("gear_kcvt4")
    base = EngineConfig(batch=2, capacity=64, policy=pol, eos_id=EOS,
                        prefill_mode="streaming")
    rng = np.random.RandomState(1)
    sys_prompt = rng.randint(4, 64, size=24)
    sfx = [rng.randint(4, 64, size=6) for _ in range(4)]
    reqs = lambda: [Request(rid=i, tokens=np.concatenate([sys_prompt, sfx[i]]),
                            max_new_tokens=5) for i in range(4)]

    def run(eng):
        s = Scheduler(eng)
        for r in reqs():
            s.submit(r)
        return {r.rid: r.tokens for r in s.run_continuous()}, s.last_stats

    cold, _ = run(Engine(model, params, base))
    eng_w = Engine(model, params, dataclasses.replace(
        base, layout="paged", prefix_cache=True))
    warm, st = run(eng_w)
    for rid in cold:
        np.testing.assert_array_equal(cold[rid], warm[rid], err_msg=str(rid))
    eng_w.pool.check()
    assert st["prefix_hit_rate"] > 0
    assert st["pool"]["shared_pages"] > 0


# ---------------------------------------------------------------------------
# Admission: pool-bytes-limited, OOM queues instead of crashing


def test_oom_admission_queues_not_crashes():
    """Pool sized for ONE in-flight request on a 2-slot engine: every
    request still completes (serially), bit-identical to a roomy pool."""
    model, params = _model(TINY)
    pol = _small("gear_kcvt4")
    ecfg = EngineConfig(batch=2, capacity=CAP, policy=pol, eos_id=EOS,
                        layout="paged")
    roomy, _ = _run(Engine(model, params, ecfg))
    # need = raw prompt (<= 8) + max_new - 1 <= 16 -> 2 pages of n_b=8
    tight = Engine(model, params, dataclasses.replace(ecfg, pool_pages=3))
    got, stats = _run(tight)
    for rid in roomy:
        np.testing.assert_array_equal(roomy[rid], got[rid], err_msg=str(rid))
    tight.pool.check()
    assert stats["pool"]["admits"] == 5        # every request got a slot
    # finished slots keep their reservation (like dense rows keep data)
    # until re-spliced or reset; dropping them returns every page
    for s in range(2):
        tight.pool.release_slot(s)
    assert tight.pool.free_pages == 2


def test_submit_rejects_impossible_request():
    model, params = _model(TINY)
    eng = Engine(model, params, EngineConfig(
        batch=2, capacity=CAP, policy=_small("gear_kcvt4"),
        layout="paged", pool_pages=2))        # 1 allocatable page
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="pool pages"):
        sched.submit(Request(rid=0, tokens=np.arange(4), max_new_tokens=30))


def test_pool_exhausted_is_retryable():
    pool = PagePool(n_pages=4, batch=2, n_chunks=6, page_bytes=128)
    pool.admit(0, 2)
    with pytest.raises(PoolExhausted):
        pool.admit(1, 2)
    pool.check()                               # state unchanged by the raise
    assert pool.free_pages == 1
    pool.release_slot(0)
    assert len(pool.admit(1, 2)) == 2          # retry succeeds


# ---------------------------------------------------------------------------
# Typed config shim


def test_engine_config_enum_coercion():
    pol = _small("gear_kcvt4")
    ecfg = EngineConfig(batch=1, capacity=CAP, policy=pol, fused="interpret",
                        prefill_mode="streaming", layout="paged")
    assert ecfg.fused is AttendPath.INTERPRET
    assert ecfg.prefill_mode is PrefillMode.STREAMING
    assert ecfg.layout is CacheLayout.PAGED
    # str-mixin: legacy string comparisons keep working
    assert ecfg.fused == "interpret" and str(ecfg.layout) == "paged"
    # enum members pass through unchanged
    assert EngineConfig(batch=1, capacity=CAP, policy=pol,
                        fused=AttendPath.OFF).fused is AttendPath.OFF


def test_engine_config_rejects_bad_knobs():
    pol = _small("gear_kcvt4")
    with pytest.raises(ValueError, match="fused"):
        EngineConfig(batch=1, capacity=CAP, policy=pol, fused="sometimes")
    with pytest.raises(ValueError, match="layout"):
        EngineConfig(batch=1, capacity=CAP, policy=pol, layout="ragged")
    with pytest.raises(ValueError, match="not both"):
        EngineConfig(batch=1, capacity=CAP, policy=pol, layout="paged",
                     pool_pages=4, pool_bytes=1 << 20)
    with pytest.raises(ValueError, match="pool_pages"):
        EngineConfig(batch=1, capacity=CAP, policy=pol, pool_pages=4)
    with pytest.raises(ValueError, match="fp16"):
        model, params = _model(TINY)
        Engine(model, params, EngineConfig(batch=1, capacity=CAP, policy=FP16,
                                           layout="paged"))


def test_cache_view_facade():
    """new_view returns the layout's CacheView; both satisfy the protocol
    and the dense view reproduces the raw-tree API bit-for-bit."""
    model, params = _model(TINY)
    pol = _small("gear_kcvt4")
    ecfg = EngineConfig(batch=2, capacity=CAP, policy=pol)
    eng_d = Engine(model, params, ecfg)
    eng_p = Engine(model, params, dataclasses.replace(ecfg, layout="paged"))
    vd, vp = eng_d.new_view(), eng_p.new_view()
    assert isinstance(vd, DenseCacheView) and isinstance(vd, CacheView)
    assert isinstance(vp, PagedCacheView) and isinstance(vp, CacheView)
    assert vd.can_admit(10**9)                 # slot-count-limited
    assert vp.can_admit(CAP) and not vp.can_admit(10**9)

    prompt = _pad(_requests()[0].tokens, PROMPT_PAD)[None]
    b1 = {"tokens": jnp.asarray(prompt, jnp.int32)}
    lv = vd.prefill_slot(b1, 0)
    caches = eng_d.init_caches()
    lr, caches = eng_d.prefill_slot(b1, caches, 0)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lr))
    tok = {"tokens": jnp.asarray([[5], [7]], jnp.int32)}
    pos = jnp.asarray([PROMPT_PAD, 0], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(vd.decode(tok, pos)),
        np.asarray(eng_d.decode(tok, caches, pos)[0]))
    vp.prefill_slot(b1, 1, reserve_tokens=16)
    vp.decode(tok, pos[::-1])
    vp.reset_slot(1)
    eng_p.pool.check()


# ---------------------------------------------------------------------------
# Hypothesis property: allocator refcount conservation

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:                                    # fast lane w/o extras
    HAS_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class hyp_st:                                      # placeholder strategies
        integers = lists = tuples = staticmethod(lambda *a, **k: None)


def _pool_interleaving(ops):
    """Drive random admit / release / retain(COW) / store-free interleavings
    and audit the allocator's invariants after every op: no page both free
    and live, no double frees, page 0 never allocated, byte accounting
    exact, and every reference eventually returned."""
    pool = PagePool(n_pages=9, batch=3, n_chunks=6, page_bytes=64)
    handles: list[int] = []
    for kind, slot, n in ops:
        if kind == 0:                               # admit (maybe sharing)
            if pool.slot_pages(slot).size:
                pool.release_slot(slot)
            live = [h for h in handles if pool.refcount(h) > 0]
            shared = live[: n // 2]
            try:
                pool.admit(slot, min(n + len(shared), pool.n_chunks),
                           shared=shared)
            except PoolExhausted:
                pass
        elif kind == 1:                             # release a slot
            pool.release_slot(slot)
        elif kind == 2:                             # trie retain
            pages = pool.slot_pages(slot)
            if pages.size:
                handles.append(pool.retain(int(pages[n % pages.size])))
        elif kind == 3 and handles:                 # trie eviction
            pool.release(handles.pop(n % len(handles)))
        pool.check()
        assert pool.used_bytes == pool.used_pages * pool.page_bytes
        assert pool.used_pages + pool.free_pages == pool.n_pages - 1
    for slot in range(3):
        pool.release_slot(slot)
    for h in handles:
        pool.release(h)
    pool.check()
    assert pool.free_pages == pool.n_pages - 1      # everything came back


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@given(ops=hyp_st.lists(
    hyp_st.tuples(hyp_st.integers(0, 3),      # op kind
                  hyp_st.integers(0, 2),      # slot
                  hyp_st.integers(1, 5)),     # page count / page pick
    min_size=1, max_size=60))
@settings(max_examples=50, deadline=None)
def test_pool_refcounts_under_random_interleaving(ops):
    _pool_interleaving(ops)


def test_pool_refcounts_seeded_interleavings():
    """Deterministic stand-in for the hypothesis property (runs with or
    without the extra): 32 seeded random op sequences."""
    for seed in range(32):
        rng = np.random.RandomState(seed)
        ops = [(int(rng.randint(0, 4)), int(rng.randint(0, 3)),
                int(rng.randint(1, 6)))
               for _ in range(int(rng.randint(1, 61)))]
        _pool_interleaving(ops)


def test_pages_needed():
    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2
