import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only the dry-run (and the
# explicitly marked multi-device tests, which re-exec in a subprocess)
# use fake device counts.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
