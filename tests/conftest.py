import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only the dry-run (and the
# explicitly marked multi-device tests, which re-exec in a subprocess)
# use fake device counts.

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # Also registered in pyproject.toml; kept here so a bare `pytest tests/`
    # without the ini file still knows the lanes.
    config.addinivalue_line(
        "markers",
        "slow: compile-heavy / subprocess test, excluded from the CI smoke "
        'lane (-m "not slow")')
    config.addinivalue_line(
        "markers",
        "multidevice: re-execs in a subprocess with a fake multi-device CPU "
        "topology (xla_force_host_platform_device_count)")
    config.addinivalue_line(
        "markers",
        "kernel: exercises Pallas kernel code (interpret mode on CPU); the "
        "CI tests-kernels lane runs `pytest -m kernel`")
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection / resilience test (seeded FaultInjector "
        "schedules); the CI tests-chaos lane runs `pytest -m chaos`")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
