"""Beyond-paper adaptive rank allocation (paper §6.1 future work)."""

import jax
import jax.numpy as jnp

from repro.core.adaptive import allocate_ranks, adaptive_error_vs_uniform


def test_allocation_respects_budget(rng):
    spectra = jnp.sort(jax.random.uniform(rng, (6, 8)), axis=-1)[:, ::-1]
    ranks = allocate_ranks(spectra, budget=24)
    assert int(ranks.sum()) == 24
    assert int(ranks.max()) <= 8


def test_allocation_prefers_energetic_heads():
    spectra = jnp.stack([jnp.full((4,), 10.0), jnp.full((4,), 0.1)])
    ranks = allocate_ranks(spectra, budget=4)
    assert int(ranks[0]) == 4 and int(ranks[1]) == 0


def test_adaptive_never_worse_than_uniform(rng):
    H, n, d = 6, 128, 32
    scale = jnp.logspace(0, 1, H)[:, None, None]
    resid = jax.random.normal(rng, (H, n, d)) * scale
    # add per-head low-rank structure so rank demand differs
    u = jax.random.normal(jax.random.fold_in(rng, 1), (H, n, 4))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (H, 4, d))
    resid = resid + 3.0 * scale * (u @ v)
    # rank 2 < planted rank 4: heterogeneous demand — adaptive wins big
    res2 = adaptive_error_vs_uniform(resid, rank=2, key=rng)
    assert res2["adaptive"] < 0.8 * res2["uniform"]
    # rank 4 == planted rank: uniform is already optimal; adaptive may pay
    # <=2% power-iteration noise from the larger max_rank subspace
    res4 = adaptive_error_vs_uniform(resid, rank=4, key=rng)
    assert res4["adaptive"] <= res4["uniform"] * 1.02 + 1e-6
