"""Distributed tests: sharding rules, train loop on a mesh, PowerSGD,
checkpoint/restore/elastic-rescale.  Multi-device cases re-exec in a
subprocess so the fake host-device count never leaks into other tests."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.dist.sharding import fit_spec
from repro.launch.mesh import make_test_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str, devices: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class FakeMesh:
    shape = {"data": 4, "model": 4}


class TestFitSpec:
    def test_migrates_axis(self):
        ps = fit_spec(P("model", None), (122753, 2304), FakeMesh())
        assert tuple(ps) == (None, "model")

    def test_drops_axis(self):
        ps = fit_spec(P("model",), (7,), FakeMesh())
        assert tuple(ps) == (None,)

    def test_keeps_legal(self):
        ps = fit_spec(P(None, "model"), (8, 16), FakeMesh())
        assert tuple(ps) == (None, "model")

    # --- edge cases beyond the seed's three -------------------------------

    def test_multi_axis_group_kept_when_divisible(self):
        ps = fit_spec(P(("data", "model"), None), (16, 4), FakeMesh())
        assert tuple(ps) == (("data", "model"), None)

    def test_multi_axis_group_splits_and_migrates(self):
        # dim0 (8) only fits the "data" prefix (4); the leftover "model"
        # axis migrates to dim1 (64).
        ps = fit_spec(P(("data", "model"), None), (8, 64), FakeMesh())
        assert tuple(ps) == ("data", "model")

    def test_multi_axis_group_drops_when_nothing_fits(self):
        ps = fit_spec(P(("data", "model"),), (7,), FakeMesh())
        assert tuple(ps) == (None,)

    def test_partially_migrated_group_rehomes_its_remainder(self):
        # dim0 fits nothing; "data" migrates to dim1 and the leftover
        # "model" keeps looking and lands on dim2.
        ps = fit_spec(P(("data", "model"), None, None), (2, 4, 4), FakeMesh())
        assert tuple(ps) == (None, "data", "model")

    def test_zero_size_dim_accepts_any_sharding(self):
        ps = fit_spec(P("model", None), (0, 5), FakeMesh())
        assert tuple(ps) == ("model", None)

    def test_mesh_axes_absent_from_spec_are_fine(self):
        class PodMesh:
            shape = {"pod": 2, "data": 4, "model": 4}
        ps = fit_spec(P(None, "model"), (8, 16), PodMesh())
        assert tuple(ps) == (None, "model")

    def test_spec_axis_unknown_to_mesh_is_dropped(self):
        ps = fit_spec(P("tensor", None), (8, 8), FakeMesh())
        assert tuple(ps) == (None, None)

    def test_short_spec_padded_to_rank(self):
        ps = fit_spec(P("model"), (8, 6), FakeMesh())
        assert tuple(ps) == ("model", None)

    def test_no_migration_when_disabled(self):
        ps = fit_spec(P("model", None), (7, 16), FakeMesh(), migrate=False)
        assert tuple(ps) == (None, None)

    def test_overlong_spec_rejected(self):
        with pytest.raises(ValueError):
            fit_spec(P("model", None), (16,), FakeMesh())


@pytest.mark.slow
@pytest.mark.multidevice
def test_train_restore_deterministic(tmp_path):
    """6 steps straight == 3 steps + restart + 3 steps (bitwise metrics)."""
    out = run_subprocess(f"""
        import jax, dataclasses, json
        from repro.configs import smoke_config
        from repro.models.model import build_model
        from repro.launch.mesh import make_test_mesh
        from repro.train.state import RunConfig
        from repro.train.loop import train_loop
        from repro.data.synthetic import DataConfig

        cfg = smoke_config("minicpm-2b")
        m = build_model(cfg)
        mesh = make_test_mesh(2, 2)
        dc = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        logs = []
        run = RunConfig(total_steps=6, warmup_steps=1, microbatches=2, remat=True,
                        zero1=True, ckpt_dir="{tmp_path}/a", ckpt_every=0, log_every=1)
        s1 = train_loop(m, mesh, run, dc, log_fn=logs.append)
        runb = dataclasses.replace(run, total_steps=3, ckpt_dir="{tmp_path}/b", ckpt_every=0)
        import repro.ckpt.checkpoint as ck
        s2 = train_loop(m, mesh, runb, dc, log_fn=lambda *_: None)
        ck.save("{tmp_path}/b", 3, s2)
        runc = dataclasses.replace(run, total_steps=6, ckpt_dir="{tmp_path}/b", ckpt_every=0)
        s3 = train_loop(m, mesh, runc, dc, log_fn=lambda *_: None)
        import numpy as np
        p1 = jax.tree.leaves(s1.params); p3 = jax.tree.leaves(s3.params)
        diff = max(float(abs(np.asarray(a)-np.asarray(b)).max()) for a, b in zip(p1, p3))
        print("MAXDIFF", diff)
    """)
    diff = float(out.split("MAXDIFF")[1].strip())
    assert diff < 1e-5


@pytest.mark.slow
@pytest.mark.multidevice
def test_elastic_rescale_restore(tmp_path):
    """Checkpoint on a 2×2 mesh restores onto a 4×1 mesh (mesh-independent)."""
    run_subprocess(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import build_model
        from repro.launch.mesh import make_test_mesh
        from repro.train.state import RunConfig, init_train_state
        from repro.train.loop import train_state_shardings
        from repro.dist import sharding as shd
        import repro.ckpt.checkpoint as ck

        cfg = smoke_config("minicpm-2b")
        m = build_model(cfg)
        run = RunConfig(ckpt_every=0)
        mesh1 = make_test_mesh(2, 2)
        with mesh1:
            state = init_train_state(m.init(jax.random.PRNGKey(0)), run)
            sh1 = train_state_shardings(cfg, mesh1, state, run)
            state = jax.device_put(state, sh1)
            ck.save("{tmp_path}/ck", 1, state)
        mesh2 = make_test_mesh(4, 1)
        with mesh2:
            tgt = init_train_state(m.init(jax.random.PRNGKey(0)), run)
            sh2 = train_state_shardings(cfg, mesh2, tgt, run)
            restored = ck.restore("{tmp_path}/ck", 1, tgt, sh2)
        a = jax.tree.leaves(state.params)[0]
        b = jax.tree.leaves(restored.params)[0]
        assert np.allclose(np.asarray(a), np.asarray(b)), "elastic restore mismatch"
        print("ELASTIC_OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
def test_powersgd_runs_on_pod_mesh(tmp_path):
    run_subprocess(f"""
        import dataclasses
        from repro.configs import smoke_config
        from repro.models.model import build_model
        from repro.launch.mesh import make_test_mesh
        from repro.train.state import RunConfig
        from repro.train.loop import train_loop
        from repro.data.synthetic import DataConfig
        cfg = smoke_config("minicpm-2b")
        m = build_model(cfg)
        mesh = make_test_mesh(data=2, model=2, pod=2)
        run = RunConfig(total_steps=2, warmup_steps=1, microbatches=1, remat=False,
                        zero1=False, grad_compression="powersgd", powersgd_rank=2,
                        powersgd_min_size=4096, ckpt_dir="{tmp_path}/ps",
                        ckpt_every=0, log_every=1)
        dc = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        logs = []
        train_loop(m, mesh, run, dc, log_fn=logs.append)
        assert any("compressed_bytes" in l and "compressed_bytes=0 " not in l for l in logs), logs
        print("POWERSGD_OK")
    """, devices=8)


@pytest.mark.slow
@pytest.mark.multidevice
def test_serving_on_mesh(tmp_path):
    run_subprocess("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import smoke_config
        from repro.models.model import build_model
        from repro.core.policy import named_policy
        from repro.launch.mesh import make_test_mesh
        from repro.serving.engine import Engine, EngineConfig
        cfg = smoke_config("minicpm-2b")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)
        mesh = make_test_mesh(2, 2)
        with mesh:
            eng = Engine(m, params, EngineConfig(batch=4, capacity=96, policy=pol), mesh=mesh)
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, cfg.vocab_size)}
            toks, stats = eng.generate(batch, 8)
        assert toks.shape == (4, 8)
        print("SERVE_MESH_OK")
    """)
