"""Integration test of the dry-run machinery itself, on a tiny fake mesh.

Exercises build_cell → lower → compile → cost/collective extraction for one
cell of each mode (train/prefill/decode) with a reduced config, in a
subprocess so the fake device count never leaks."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
@pytest.mark.multidevice
def test_dryrun_machinery_small_mesh(tmp_path):
    body = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, json, dataclasses
        import repro.launch.dryrun as dr
        import repro.configs as C

        # shrink: tiny smoke config + tiny shapes on a 2x2 mesh
        smoke = C.smoke_config("minicpm-2b")
        C._SMOKE = smoke
        orig_get = C.get_config
        dr.get_config = lambda name: smoke
        import repro.perf.roofline as rl
        rl_model_flops = rl.model_flops
        dr.SHAPES = {
            "train_4k": dataclasses.replace(C.SHAPES["train_4k"], seq_len=64, global_batch=4),
            "prefill_32k": dataclasses.replace(C.SHAPES["prefill_32k"], seq_len=128, global_batch=2),
            "decode_32k": dataclasses.replace(C.SHAPES["decode_32k"], seq_len=128, global_batch=4),
        }
        from repro.launch.mesh import make_test_mesh
        dr.make_production_mesh = lambda multi_pod=False: make_test_mesh(2, 2)

        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            rec = dr.dryrun_cell("minicpm-2b", shape, multi_pod=False, microbatches=2)
            assert rec["roofline"]["compute_s"] > 0, shape
            assert rec["loop_cost"]["flops"] > 0, shape
            assert "collectives" in rec, shape
            print(shape, "OK", rec["roofline"]["bottleneck"])
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("OK") == 3
