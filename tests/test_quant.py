"""Unit tests: packing, quantization backbones, outlier filter, power iteration."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import packing, quant, outlier, lowrank


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(3, 5, 64), (2, 128), (1, 1, 1, 32)])
def test_pack_roundtrip(bits, shape, rng):
    codes = jax.random.randint(rng, shape, 0, 2**bits)
    assert (packing.unpack(packing.pack(codes, bits), bits, shape[-1]) == codes).all()


def test_pack_rejects_bad_width():
    with pytest.raises(ValueError):
        packing.pack(jnp.zeros((4, 7), jnp.int32), 2)
    with pytest.raises(ValueError):
        packing.codes_per_lane(3)


@pytest.mark.parametrize("scheme,group", [
    ("per_token_group", 32), ("per_channel", None), ("per_channel", 16),
    ("per_token", None), ("per_token", 32),
])
def test_quant_8bit_accurate(scheme, group, rng):
    x = jax.random.normal(rng, (2, 64, 64))
    qt = quant.quantize(x, 8, scheme, group)
    err = jnp.linalg.norm(x - quant.dequantize(qt)) / jnp.linalg.norm(x)
    assert err < 0.01


def test_quant_monotone_in_bits(rng):
    x = jax.random.normal(rng, (4, 128, 64))
    errs = [float(quant.quant_error(x, b, "per_channel")) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_quant_constant_group_safe():
    x = jnp.ones((2, 16, 32))
    qt = quant.quantize(x, 4, "per_token")
    assert jnp.allclose(quant.dequantize(qt), x, atol=1e-5)


@pytest.mark.parametrize("axis", ["token", "channel"])
def test_outlier_split_exact(axis, rng):
    x = jax.random.normal(rng, (3, 32, 16))
    sp, rem = outlier.filter_outliers(x, 0.1, axis)
    assert jnp.allclose(rem + outlier.densify(sp), x, atol=1e-6)
    # removed entries are the extremes: remainder range is within original
    assert float(jnp.abs(rem).max()) <= float(jnp.abs(x).max())


def test_outlier_reduces_dynamic_range(rng):
    x = jax.random.normal(rng, (2, 64, 32))
    x = x.at[:, 0, 0].set(100.0)
    _, rem = outlier.filter_outliers(x, 0.05, "token")
    assert float(jnp.abs(rem).max()) < 50.0


def test_power_iteration_matches_svd(rng):
    u = jax.random.normal(rng, (2, 64, 6))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 6))
    x = u @ jnp.swapaxes(v, -1, -2)
    approx = lowrank.lowrank_approx(x, 6, iters=8)
    exact = lowrank.svd_topr(x, 6)
    assert float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact)) < 1e-3


def test_power_iteration_error_decreases_with_rank(rng):
    x = jax.random.normal(rng, (1, 96, 48))
    errs = []
    for r in (1, 4, 16):
        a = lowrank.lowrank_approx(x, r, iters=6)
        errs.append(float(jnp.linalg.norm(x - a)))
    assert errs[0] > errs[1] > errs[2]
