"""Streaming-buffer cache semantics (paper Algorithm 1) + attend equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import (CacheConfig, named_policy, init_layer_cache,
                        prefill_layer_cache, append_token, attend, dense_kv)
from repro.kernels.ops import gear_attend

B, H, DH = 2, 2, 64


def small_policy(name, nb=16):
    return dataclasses.replace(named_policy(name), buffer_size=nb,
                               group=min(16, named_policy(name).group))


def build(policy, n=40, cap=64, key=0):
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=cap, policy=policy)
    k = jax.random.normal(jax.random.PRNGKey(key), (B, H, n, DH))
    v = jax.random.normal(jax.random.PRNGKey(key + 1), (B, H, n, DH))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    return cfg, cache, k, v


@pytest.mark.parametrize("pol", ["gear_kivi2", "gear_kcvt4", "gear_l_kivi2", "kivi2"])
def test_prefill_roundtrip_error_bounded(pol):
    cfg, cache, k, v = build(small_policy(pol))
    kh, vh = dense_kv(cfg, cache)
    rel = jnp.linalg.norm(kh[:, :, :40] - k) / jnp.linalg.norm(k)
    assert float(rel) < 0.55  # 2-bit worst case


def test_buffer_tokens_exact():
    """Tokens still in the streaming buffer round-trip exactly (fp16)."""
    cfg, cache, k, v = build(small_policy("gear_kivi2"), n=40)  # 40 = 2 chunks + 8 buf
    kh, _ = dense_kv(cfg, cache)
    buffered = k[:, :, 32:40]
    assert jnp.allclose(kh[:, :, 32:40], buffered, atol=2e-2)  # bf16 buffer


def test_append_compresses_every_nb_steps():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=32)
    nb = cfg.chunk
    assert int(cache.length) == 32
    before = cache.k_packed.copy()
    for t in range(nb):
        kt = jax.random.normal(jax.random.PRNGKey(100 + t), (B, H, DH))
        cache = append_token(cfg, cache, kt, kt)
    # chunk 2 (tokens 32..47) must now be compressed into packed storage
    assert int(cache.length) == 32 + nb
    assert not (cache.k_packed[:, :, 32:48] == before[:, :, 32:48]).all()


def test_attend_matches_dense_reference():
    for pol in ("gear_kivi2", "gear_kcvt4"):
        cfg, cache, *_ = build(small_policy(pol), n=44)
        q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, DH))
        out_f = attend(cfg, cache, q, scale=DH**-0.5, use_factored=True)
        out_d = attend(cfg, cache, q, scale=DH**-0.5, use_factored=False)
        assert jnp.allclose(out_f, out_d, atol=2e-2)


def test_kernel_ops_path_matches_core():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=44)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, DH))
    # core attend runs the bf16 fused-dequant path; the ops/kernel contract
    # is f32 — agreement within bf16 resolution.
    o1 = attend(cfg, cache, q, scale=DH**-0.5)
    o2 = gear_attend(cfg, cache, q, scale=DH**-0.5)
    o3 = gear_attend(cfg, cache, q, scale=DH**-0.5, force_kernel=True, interpret=True)
    assert jnp.allclose(o2, o3, atol=1e-4)   # oracle == kernel exactly-ish
    assert jnp.allclose(o1, o2, atol=3e-2)   # bf16 vs f32 path


def test_append_jit_cond_static():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=32)
    ap = jax.jit(lambda c, kt, vt: append_token(cfg, c, kt, vt))
    kt = jnp.ones((B, H, DH))
    c = ap(cache, kt, kt)
    assert int(c.length) == 33


def test_fp16_and_window_caches():
    pol = named_policy("fp16")
    cfgf = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="fp16")
    cf = prefill_layer_cache(cfgf, init_layer_cache(cfgf),
                             jnp.ones((B, H, 10, DH)), jnp.ones((B, H, 10, DH)))
    q = jnp.ones((B, H, DH))
    assert attend(cfgf, cf, q, DH**-0.5).shape == (B, H, DH)

    cfgw = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="window", window=8)
    cw = prefill_layer_cache(cfgw, init_layer_cache(cfgw),
                             jnp.ones((B, H, 20, DH)), jnp.ones((B, H, 20, DH)))
    assert int(cw.length) == 20
    # ring buffer holds only the last 8 positions
    assert int((cw.pos >= 12).sum()) == 8
