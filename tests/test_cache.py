"""Streaming-buffer cache semantics (paper Algorithm 1) + attend equivalence
+ the streaming-chunked-prefill parity sweep (compress-as-you-go vs the
monolithic batched compression event)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import (CacheConfig, named_policy, init_layer_cache,
                        prefill_layer_cache, streaming_prefill_layer_cache,
                        append_token, attend, dense_kv,
                        reset_slot, prefill_into_slot, fresh_batch1_cache,
                        packing)
from repro.kernels.ops import fused_supported, gear_attend

B, H, DH = 2, 2, 64


def small_policy(name, nb=16):
    return dataclasses.replace(named_policy(name), buffer_size=nb,
                               group=min(16, named_policy(name).group))


def build(policy, n=40, cap=64, key=0):
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=cap, policy=policy)
    k = jax.random.normal(jax.random.PRNGKey(key), (B, H, n, DH))
    v = jax.random.normal(jax.random.PRNGKey(key + 1), (B, H, n, DH))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    return cfg, cache, k, v


@pytest.mark.parametrize("pol", ["gear_kivi2", "gear_kcvt4", "gear_l_kivi2", "kivi2"])
def test_prefill_roundtrip_error_bounded(pol):
    cfg, cache, k, v = build(small_policy(pol))
    kh, vh = dense_kv(cfg, cache)
    rel = jnp.linalg.norm(kh[:, :, :40] - k) / jnp.linalg.norm(k)
    assert float(rel) < 0.55  # 2-bit worst case


def test_buffer_tokens_exact():
    """Tokens still in the streaming buffer round-trip exactly (fp16)."""
    cfg, cache, k, v = build(small_policy("gear_kivi2"), n=40)  # 40 = 2 chunks + 8 buf
    kh, _ = dense_kv(cfg, cache)
    buffered = k[:, :, 32:40]
    assert jnp.allclose(kh[:, :, 32:40], buffered, atol=2e-2)  # bf16 buffer


def test_append_compresses_every_nb_steps():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=32)
    nb = cfg.chunk
    assert int(cache.length[0]) == 32  # per-slot lengths
    before = cache.k_packed.copy()
    for t in range(nb):
        kt = jax.random.normal(jax.random.PRNGKey(100 + t), (B, H, DH))
        cache = append_token(cfg, cache, kt, kt)
    # chunk 2 (tokens 32..47) must now be compressed into packed storage
    assert (cache.length == 32 + nb).all()
    assert not (cache.k_packed[:, :, 32:48] == before[:, :, 32:48]).all()


def test_attend_matches_dense_reference():
    for pol in ("gear_kivi2", "gear_kcvt4"):
        cfg, cache, *_ = build(small_policy(pol), n=44)
        q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, DH))
        out_f = attend(cfg, cache, q, scale=DH**-0.5, use_factored=True)
        out_d = attend(cfg, cache, q, scale=DH**-0.5, use_factored=False)
        assert jnp.allclose(out_f, out_d, atol=2e-2)


def test_kernel_ops_path_matches_core():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=44)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, DH))
    # core attend runs the bf16 fused-dequant path; the ops/kernel contract
    # is f32 — agreement within bf16 resolution.
    o1 = attend(cfg, cache, q, scale=DH**-0.5)
    o2 = gear_attend(cfg, cache, q, scale=DH**-0.5)
    o3 = gear_attend(cfg, cache, q, scale=DH**-0.5, force_kernel=True, interpret=True)
    assert jnp.allclose(o2, o3, atol=1e-4)   # oracle == kernel exactly-ish
    assert jnp.allclose(o1, o2, atol=3e-2)   # bf16 vs f32 path


@pytest.mark.kernel
@pytest.mark.parametrize("pol", ["gear_kcvt4", "gear_kivi2"])
def test_gear_attend_ragged_per_slot(pol):
    """Mixed-length batch through the fused path: per-slot masking inside
    the kernel.  Slot lengths cover empty (0), buffer-only (< chunk), a
    chunk boundary (buffer empty), and a mixed compressed+buffer length;
    each populated slot must equal a solo batch-1 fused run bit-for-bit and
    the jnp attend path within bf16 tolerance."""
    policy = small_policy(pol)                       # nb = 16
    lengths = [0, 7, 32, 44]
    cfg = CacheConfig(batch=4, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    assert fused_supported(cfg)
    key = jax.random.PRNGKey(3)
    k = jax.random.normal(key, (4, H, 44, DH))
    v = jax.random.normal(jax.random.fold_in(key, 1), (4, H, 44, DH))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    cache = reset_slot(cfg, cache, 0)
    for s, n in ((1, 7), (2, 32)):
        cache = prefill_into_slot(cfg, cache, k[s:s + 1, :, :n], v[s:s + 1, :, :n], s)
    assert [int(x) for x in cache.length] == lengths

    q = jax.random.normal(jax.random.PRNGKey(9), (4, H * 2, DH))
    o_ref = gear_attend(cfg, cache, q, scale=DH**-0.5)
    o_krn = gear_attend(cfg, cache, q, scale=DH**-0.5,
                        force_kernel=True, interpret=True)
    o_jnp = attend(cfg, cache, q, scale=DH**-0.5)
    assert jnp.allclose(o_krn, o_ref, atol=1e-4)     # kernel == oracle
    assert (o_ref[0] == 0).all()                     # empty slot attends nothing
    cfg1 = dataclasses.replace(cfg, batch=1)
    for s, n in ((1, 7), (2, 32), (3, 44)):
        solo = prefill_layer_cache(cfg1, init_layer_cache(cfg1),
                                   k[s:s + 1, :, :n], v[s:s + 1, :, :n])
        o_solo = gear_attend(cfg1, solo, q[s:s + 1], scale=DH**-0.5)
        assert jnp.allclose(o_ref[s:s + 1], o_solo, rtol=1e-6, atol=1e-6), s
        assert jnp.allclose(o_ref[s], o_jnp[s], atol=3e-2), s  # f32 vs bf16 path


def test_append_jit_cond_static():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=32)
    ap = jax.jit(lambda c, kt, vt: append_token(cfg, c, kt, vt))
    kt = jnp.ones((B, H, DH))
    c = ap(cache, kt, kt)
    assert (c.length == 33).all()


def test_fp16_and_window_caches():
    pol = named_policy("fp16")
    cfgf = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="fp16")
    cf = prefill_layer_cache(cfgf, init_layer_cache(cfgf),
                             jnp.ones((B, H, 10, DH)), jnp.ones((B, H, 10, DH)))
    q = jnp.ones((B, H, DH))
    assert attend(cfgf, cf, q, DH**-0.5).shape == (B, H, DH)

    cfgw = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="window", window=8)
    cw = prefill_layer_cache(cfgw, init_layer_cache(cfgw),
                             jnp.ones((B, H, 20, DH)), jnp.ones((B, H, 20, DH)))
    assert (cw.length == 20).all()
    # ring buffer holds only the last 8 positions (per slot)
    assert int((cw.pos >= 12).sum()) == 8 * B


def test_reset_and_prefill_into_slot_match_solo_prefill():
    """The cache-level half of the slot-splice protocol: a slot prefilled
    in place reconstructs bit-identically to a solo batch-1 prefill, and the
    neighbouring slot is untouched."""
    cfg, cache, k, v = build(small_policy("gear_kcvt4"), n=40)

    c2 = reset_slot(cfg, cache, 1)
    assert int(c2.length[1]) == 0 and int(c2.length[0]) == 40
    kh2, _ = dense_kv(cfg, c2)
    assert (kh2[1] == 0).all()          # reset slot masks as empty

    key = jax.random.PRNGKey(7)
    k1 = jax.random.normal(key, (1, H, 24, DH))
    v1 = jax.random.normal(jax.random.fold_in(key, 1), (1, H, 24, DH))
    c3 = prefill_into_slot(cfg, c2, k1, v1, 1)
    assert int(c3.length[1]) == 24 and int(c3.length[0]) == 40

    cfg1 = dataclasses.replace(cfg, batch=1)
    solo = prefill_layer_cache(cfg1, init_layer_cache(cfg1), k1, v1)
    kh_b, vh_b = dense_kv(cfg, c3)
    kh_s, vh_s = dense_kv(cfg1, solo)
    assert (kh_b[1:2] == kh_s).all() and (vh_b[1:2] == vh_s).all()
    # slot 0 reconstructs exactly as before the splice
    kh0, _ = dense_kv(cfg, cache)
    assert (kh_b[0] == kh0[0]).all()


# ---------------------------------------------------------------------------
# Streaming chunked prefill (compress-as-you-go) parity sweep


def _qkv(n, key=3, batch=B):
    k = jax.random.normal(jax.random.PRNGKey(key), (batch, H, n, DH))
    v = jax.random.normal(jax.random.PRNGKey(key + 1), (batch, H, n, DH))
    q = jax.random.normal(jax.random.PRNGKey(key + 2), (batch, H * 2, n, DH))
    return q, k, v


def _tree_equal(a, b) -> bool:
    return all(bool((x == y).all()) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("pol", ["gear_kcvt4", "gear_kivi2", "kivi2",
                                 "gear_l_kivi2", "outlier_kivi2"])
@pytest.mark.parametrize("n", [32, 44, 7])
def test_streaming_prefill_cache_bit_identical_to_monolithic(pol, n):
    """The tentpole cache invariant: chunk-boundary (n=32), leftover-buffer
    (n=44), and buffer-only (n=7) prompts all build the exact monolithic
    cache — per-chunk compression events are batch- and chunk-count-
    invariant, so compress-as-you-go changes nothing the decoder can see."""
    policy = small_policy(pol)
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    q, k, v = _qkv(n)
    mono = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    for fused in ("off", "auto"):
        stream, out = streaming_prefill_layer_cache(
            cfg, init_layer_cache(cfg), q, k, v, DH**-0.5, fused=fused)
        assert _tree_equal(mono, stream), (pol, n, fused)
        assert out.shape == (B, H * 2, n, DH)
        assert bool(jnp.isfinite(out).all())


def _lattice(key, shape, nb, bits=4, delta=0.5):
    """K/V on the quantization lattice: every chunk-column group and token
    row contains 0 and the top level, so 4-bit quantization is lossless,
    and the zero residual makes the low-rank factors exactly zero."""
    top = (2**bits - 1) * delta
    x = delta * jax.random.randint(key, shape, 0, 2**bits).astype(jnp.float32)
    for c in range(shape[2] // nb):
        x = x.at[:, :, c * nb, :].set(0.0).at[:, :, c * nb + 1, :].set(top)
    return x.at[:, :, :, 0].set(0.0).at[:, :, :, 1].set(top)


@pytest.mark.parametrize("pol", ["kcvt4", "gear_l_kcvt4"])
def test_streaming_prefill_matches_exact_attention_on_lattice(pol):
    """Streaming == monolithic logits to 1e-5 when compression is lossless:
    on lattice K/V the compressed history dequantizes exactly, so the
    two-piece online softmax must reproduce plain causal attention — this
    pins the whole streaming pipeline (masks, chunk splits, prefix views,
    softmax merge) with no compression-error confound."""
    nb, n = 16, 48
    policy = dataclasses.replace(named_policy(pol), buffer_size=nb)
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    key = jax.random.PRNGKey(7)
    k = _lattice(key, (B, H, n, DH), nb)
    v = _lattice(jax.random.fold_in(key, 1), (B, H, n, DH), nb)
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, H * 2, n, DH))
    _, out = streaming_prefill_layer_cache(
        cfg, init_layer_cache(cfg), q, k, v, DH**-0.5)
    qf = q.reshape(B, H, 2, n, DH)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k) * DH**-0.5
    s = jnp.where(jnp.tril(jnp.ones((n, n), bool))[None, None, None], s, -1e30)
    ref = jnp.einsum("bhgqk,bhkd->bhgqd", jax.nn.softmax(s, axis=-1),
                     v).reshape(B, H * 2, n, DH)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_streaming_prefill_attention_close_on_real_data():
    """With real (lossy) compression the streaming output tracks exact
    attention to within the policy's reconstruction error — the same
    semantics gap decode already has against FP16 attention."""
    policy = small_policy("gear_kcvt4")
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    n = 44
    q, k, v = _qkv(n)
    _, out = streaming_prefill_layer_cache(
        cfg, init_layer_cache(cfg), q, k, v, DH**-0.5)
    qf = q.reshape(B, H, 2, n, DH)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k) * DH**-0.5
    s = jnp.where(jnp.tril(jnp.ones((n, n), bool))[None, None, None], s, -1e30)
    ref = jnp.einsum("bhgqk,bhkd->bhgqd", jax.nn.softmax(s, axis=-1),
                     v).reshape(B, H * 2, n, DH)
    rel = jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref)
    assert float(rel) < 0.15, float(rel)
    # tokens still inside the FP16 streaming buffer attend losslessly, so
    # the first post-buffer rows (history-free) agree much tighter
    assert jnp.allclose(out[:, :, :16], ref[:, :, :16], atol=1e-4)


def test_streaming_prefill_windowed_and_fp16_gated():
    """Non-GEAR caches have no compression event to stream."""
    pol = named_policy("fp16")
    cfgw = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="window", window=8)
    q, k, v = _qkv(16)
    with pytest.raises(ValueError, match="GEAR"):
        streaming_prefill_layer_cache(cfgw, init_layer_cache(cfgw), q, k, v,
                                      DH**-0.5)


def test_streaming_prefill_interpret_kernels_jitter_bounded():
    """Forcing the fused kernels (interpret mode) reproduces the oracle
    path up to the documented round-half ±1 code jitter between separately
    compiled programs; stats stay exact."""
    policy = small_policy("gear_kcvt4")
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    q, k, v = _qkv(44)
    mono = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    stream, out = streaming_prefill_layer_cache(
        cfg, init_layer_cache(cfg), q, k, v, DH**-0.5, fused="interpret")
    for packed_s, packed_m in ((stream.k_packed, mono.k_packed),
                               (stream.v_packed, mono.v_packed)):
        diff = jnp.abs(packing.unpack(packed_s, policy.bits, DH)
                       - packing.unpack(packed_m, policy.bits, DH))
        assert int(diff.max()) <= 1
        assert float((diff > 0).mean()) < 1e-3
    assert (stream.k_scale == mono.k_scale).all()
    assert (stream.v_scale == mono.v_scale).all()
    assert (stream.k_sp_idx == mono.k_sp_idx).all()
    assert bool(jnp.isfinite(out).all())


def test_fresh_batch1_cache_memoized():
    """The batch-1 zero tree is built once per geometry (the splice path's
    per-request allocation is hoisted — satellite of the streaming PR)."""
    policy = small_policy("gear_kcvt4")
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    one = fresh_batch1_cache(cfg)
    again = fresh_batch1_cache(dataclasses.replace(cfg, batch=1))
    assert one.k_packed is again.k_packed          # same memoized tree
    assert one.k_packed.shape[0] == 1
    other = fresh_batch1_cache(cfg, dtype=jnp.float32)
    assert other.buf_k.dtype == jnp.float32        # dtype participates in key


def test_streaming_prefill_rejects_unsupported_layouts():
    """Layout gate: the history scorer needs per-channel K stats at chunk
    granularity — finer groups and per-token-group backbones must raise at
    the cache level (and fall back to monolithic at the model level)."""
    from repro.core.cache import streaming_supported
    q, k, v = _qkv(32)
    fine = dataclasses.replace(named_policy("gear_kivi2"), buffer_size=32,
                               group=16)                    # group != chunk
    ptg = dataclasses.replace(named_policy("per_token_q4"), buffer_size=16,
                              group=16)
    for pol in (fine, ptg):
        cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                          policy=pol)
        assert not streaming_supported(cfg)
        with pytest.raises(ValueError, match="per-channel K"):
            streaming_prefill_layer_cache(cfg, init_layer_cache(cfg), q, k, v,
                                          DH**-0.5)


# ---------------------------------------------------------------------------
# ISSUE 10 satellite: fidelity probes are strictly read-only — an engine
# with probes armed produces bit-identical logits AND cache trees to an
# engine with observability off, across prompt lengths that do and do not
# close chunks (the probe only fires on closed chunks).


@pytest.mark.obs
@pytest.mark.slow
def test_fidelity_probe_never_perturbs_serving_state():
    import numpy as np
    from repro.configs.base import ModelConfig
    from repro.models.model import build_model
    from repro.serving import Engine, EngineConfig, ObsConfig

    cfg = ModelConfig(name="tiny-probe", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                      d_ff=64, vocab_size=64)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=8,
                              group=8, rank=2, rank_decode=2)
    base = EngineConfig(batch=1, capacity=48, policy=pol)
    eng_off = Engine(m, params, base)
    eng_on = Engine(m, params, dataclasses.replace(
        base, obs=ObsConfig(fidelity_every_n=1)))

    rng = np.random.RandomState(0)
    # 5 tokens: zero closed chunks (probe idle); 19/27: 2-3 closed chunks
    for plen in (5, 19, 27):
        prompt = {"tokens": jnp.asarray(rng.randint(4, 64, size=(1, plen)),
                                        jnp.int32)}
        log_off, cache_off = eng_off.prefill_slot(prompt,
                                                  eng_off.init_caches(), 0)
        log_on, cache_on = eng_on.prefill_slot(prompt,
                                               eng_on.init_caches(), 0)
        np.testing.assert_array_equal(np.asarray(log_off), np.asarray(log_on))
        for a, b in zip(jax.tree_util.tree_leaves(cache_off),
                        jax.tree_util.tree_leaves(cache_on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the probe genuinely ran on the chunk-closing prompts
    assert eng_on.obs.fidelity.reports
    assert {rp["prompt_tokens"] for rp in eng_on.obs.fidelity.reports} <= {19, 27}
