"""Streaming-buffer cache semantics (paper Algorithm 1) + attend equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import (CacheConfig, named_policy, init_layer_cache,
                        prefill_layer_cache, append_token, attend, dense_kv,
                        reset_slot, prefill_into_slot)
from repro.kernels.ops import fused_supported, gear_attend

B, H, DH = 2, 2, 64


def small_policy(name, nb=16):
    return dataclasses.replace(named_policy(name), buffer_size=nb,
                               group=min(16, named_policy(name).group))


def build(policy, n=40, cap=64, key=0):
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=cap, policy=policy)
    k = jax.random.normal(jax.random.PRNGKey(key), (B, H, n, DH))
    v = jax.random.normal(jax.random.PRNGKey(key + 1), (B, H, n, DH))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    return cfg, cache, k, v


@pytest.mark.parametrize("pol", ["gear_kivi2", "gear_kcvt4", "gear_l_kivi2", "kivi2"])
def test_prefill_roundtrip_error_bounded(pol):
    cfg, cache, k, v = build(small_policy(pol))
    kh, vh = dense_kv(cfg, cache)
    rel = jnp.linalg.norm(kh[:, :, :40] - k) / jnp.linalg.norm(k)
    assert float(rel) < 0.55  # 2-bit worst case


def test_buffer_tokens_exact():
    """Tokens still in the streaming buffer round-trip exactly (fp16)."""
    cfg, cache, k, v = build(small_policy("gear_kivi2"), n=40)  # 40 = 2 chunks + 8 buf
    kh, _ = dense_kv(cfg, cache)
    buffered = k[:, :, 32:40]
    assert jnp.allclose(kh[:, :, 32:40], buffered, atol=2e-2)  # bf16 buffer


def test_append_compresses_every_nb_steps():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=32)
    nb = cfg.chunk
    assert int(cache.length[0]) == 32  # per-slot lengths
    before = cache.k_packed.copy()
    for t in range(nb):
        kt = jax.random.normal(jax.random.PRNGKey(100 + t), (B, H, DH))
        cache = append_token(cfg, cache, kt, kt)
    # chunk 2 (tokens 32..47) must now be compressed into packed storage
    assert (cache.length == 32 + nb).all()
    assert not (cache.k_packed[:, :, 32:48] == before[:, :, 32:48]).all()


def test_attend_matches_dense_reference():
    for pol in ("gear_kivi2", "gear_kcvt4"):
        cfg, cache, *_ = build(small_policy(pol), n=44)
        q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, DH))
        out_f = attend(cfg, cache, q, scale=DH**-0.5, use_factored=True)
        out_d = attend(cfg, cache, q, scale=DH**-0.5, use_factored=False)
        assert jnp.allclose(out_f, out_d, atol=2e-2)


def test_kernel_ops_path_matches_core():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=44)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, DH))
    # core attend runs the bf16 fused-dequant path; the ops/kernel contract
    # is f32 — agreement within bf16 resolution.
    o1 = attend(cfg, cache, q, scale=DH**-0.5)
    o2 = gear_attend(cfg, cache, q, scale=DH**-0.5)
    o3 = gear_attend(cfg, cache, q, scale=DH**-0.5, force_kernel=True, interpret=True)
    assert jnp.allclose(o2, o3, atol=1e-4)   # oracle == kernel exactly-ish
    assert jnp.allclose(o1, o2, atol=3e-2)   # bf16 vs f32 path


@pytest.mark.kernel
@pytest.mark.parametrize("pol", ["gear_kcvt4", "gear_kivi2"])
def test_gear_attend_ragged_per_slot(pol):
    """Mixed-length batch through the fused path: per-slot masking inside
    the kernel.  Slot lengths cover empty (0), buffer-only (< chunk), a
    chunk boundary (buffer empty), and a mixed compressed+buffer length;
    each populated slot must equal a solo batch-1 fused run bit-for-bit and
    the jnp attend path within bf16 tolerance."""
    policy = small_policy(pol)                       # nb = 16
    lengths = [0, 7, 32, 44]
    cfg = CacheConfig(batch=4, kv_heads=H, head_dim=DH, capacity=64, policy=policy)
    assert fused_supported(cfg)
    key = jax.random.PRNGKey(3)
    k = jax.random.normal(key, (4, H, 44, DH))
    v = jax.random.normal(jax.random.fold_in(key, 1), (4, H, 44, DH))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    cache = reset_slot(cfg, cache, 0)
    for s, n in ((1, 7), (2, 32)):
        cache = prefill_into_slot(cfg, cache, k[s:s + 1, :, :n], v[s:s + 1, :, :n], s)
    assert [int(x) for x in cache.length] == lengths

    q = jax.random.normal(jax.random.PRNGKey(9), (4, H * 2, DH))
    o_ref = gear_attend(cfg, cache, q, scale=DH**-0.5)
    o_krn = gear_attend(cfg, cache, q, scale=DH**-0.5,
                        force_kernel=True, interpret=True)
    o_jnp = attend(cfg, cache, q, scale=DH**-0.5)
    assert jnp.allclose(o_krn, o_ref, atol=1e-4)     # kernel == oracle
    assert (o_ref[0] == 0).all()                     # empty slot attends nothing
    cfg1 = dataclasses.replace(cfg, batch=1)
    for s, n in ((1, 7), (2, 32), (3, 44)):
        solo = prefill_layer_cache(cfg1, init_layer_cache(cfg1),
                                   k[s:s + 1, :, :n], v[s:s + 1, :, :n])
        o_solo = gear_attend(cfg1, solo, q[s:s + 1], scale=DH**-0.5)
        assert jnp.allclose(o_ref[s:s + 1], o_solo, rtol=1e-6, atol=1e-6), s
        assert jnp.allclose(o_ref[s], o_jnp[s], atol=3e-2), s  # f32 vs bf16 path


def test_append_jit_cond_static():
    cfg, cache, *_ = build(small_policy("gear_kivi2"), n=32)
    ap = jax.jit(lambda c, kt, vt: append_token(cfg, c, kt, vt))
    kt = jnp.ones((B, H, DH))
    c = ap(cache, kt, kt)
    assert (c.length == 33).all()


def test_fp16_and_window_caches():
    pol = named_policy("fp16")
    cfgf = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="fp16")
    cf = prefill_layer_cache(cfgf, init_layer_cache(cfgf),
                             jnp.ones((B, H, 10, DH)), jnp.ones((B, H, 10, DH)))
    q = jnp.ones((B, H, DH))
    assert attend(cfgf, cf, q, DH**-0.5).shape == (B, H, DH)

    cfgw = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64,
                       policy=pol, kind="window", window=8)
    cw = prefill_layer_cache(cfgw, init_layer_cache(cfgw),
                             jnp.ones((B, H, 20, DH)), jnp.ones((B, H, 20, DH)))
    assert (cw.length == 20).all()
    # ring buffer holds only the last 8 positions (per slot)
    assert int((cw.pos >= 12).sum()) == 8 * B


def test_reset_and_prefill_into_slot_match_solo_prefill():
    """The cache-level half of the slot-splice protocol: a slot prefilled
    in place reconstructs bit-identically to a solo batch-1 prefill, and the
    neighbouring slot is untouched."""
    cfg, cache, k, v = build(small_policy("gear_kcvt4"), n=40)

    c2 = reset_slot(cfg, cache, 1)
    assert int(c2.length[1]) == 0 and int(c2.length[0]) == 40
    kh2, _ = dense_kv(cfg, c2)
    assert (kh2[1] == 0).all()          # reset slot masks as empty

    key = jax.random.PRNGKey(7)
    k1 = jax.random.normal(key, (1, H, 24, DH))
    v1 = jax.random.normal(jax.random.fold_in(key, 1), (1, H, 24, DH))
    c3 = prefill_into_slot(cfg, c2, k1, v1, 1)
    assert int(c3.length[1]) == 24 and int(c3.length[0]) == 40

    cfg1 = dataclasses.replace(cfg, batch=1)
    solo = prefill_layer_cache(cfg1, init_layer_cache(cfg1), k1, v1)
    kh_b, vh_b = dense_kv(cfg, c3)
    kh_s, vh_s = dense_kv(cfg1, solo)
    assert (kh_b[1:2] == kh_s).all() and (vh_b[1:2] == vh_s).all()
    # slot 0 reconstructs exactly as before the splice
    kh0, _ = dense_kv(cfg, cache)
    assert (kh_b[0] == kh0[0]).all()
