"""Unit tests for the perf tooling: jaxpr cost model, HLO collective parser,
roofline math, LR schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.schedule import lr_at
from repro.perf.hlo_stats import collective_stats, _shape_bytes
from repro.perf.jaxpr_cost import trace_cost
from repro.perf.roofline import roofline, model_flops, HW
from repro.configs import get_config, SHAPES


class TestJaxprCost:
    def test_matmul_flops_exact(self):
        c = trace_cost(lambda a, b: a @ b, jnp.zeros((128, 256)), jnp.zeros((256, 64)))
        assert c["flops"] == 2 * 128 * 256 * 64

    def test_scan_multiplies_trip_count(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c @ jnp.ones((32, 32)), None),
                                x, None, length=7)[0]
        c = trace_cost(f, jnp.zeros((32, 32)))
        assert c["flops"] == 7 * 2 * 32**3

    def test_elementwise_zero_bytes(self):
        c = trace_cost(lambda x: jnp.tanh(x) + 1.0, jnp.zeros((1024, 1024)))
        assert c["bytes"] == 0.0

    def test_grad_roughly_3x_forward(self):
        f = lambda w, x: jnp.sum((x @ w) ** 2)
        w, x = jnp.zeros((64, 64)), jnp.zeros((128, 64))
        fwd = trace_cost(f, w, x)["flops"]
        bwd = trace_cost(lambda w, x: jax.grad(f)(w, x), w, x)["flops"]
        assert 2.0 <= bwd / fwd <= 4.0


class TestHloStats:
    HLO = """
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups={}
  %ag = bf16[64,512]{1,0} all-gather(%ar), dimensions={0}
  %x = f32[4,4]{1,0} add(%p, %p)
"""

    def test_counts_and_bytes(self):
        st = collective_stats(self.HLO)
        assert st["all-reduce"]["count"] == 1
        assert st["all-gather"]["count"] == 1
        assert st["all-reduce"]["operand_bytes"] == 128 * 256 * 4
        assert st["total_count"] == 2

    def test_shape_bytes(self):
        assert _shape_bytes("f32[8,8]{1,0}") == 256
        assert _shape_bytes("bf16[10]") == 20


class TestRoofline:
    def test_terms_and_bottleneck(self):
        rl = roofline(hlo_flops=1e15, hlo_bytes=1e12, collective_bytes=1e10,
                      chips=256, model_flops_total=5e14)
        assert abs(rl.compute_s - 1e15 / (256 * HW["peak_flops"])) < 1e-12
        assert rl.bottleneck in ("compute", "memory", "collective")
        assert 0 < rl.flops_efficiency <= 1.0

    def test_model_flops_rwkv_has_no_kv_read(self):
        r = get_config("rwkv6-3b")
        m = get_config("minicpm-2b")
        s = SHAPES["decode_32k"]
        # per active-param flop, rwkv decode must be cheaper (no cache reads)
        assert (model_flops(r, s) / r.active_param_count()
                < model_flops(m, s) / m.active_param_count())

    def test_model_flops_window_caps_local_layers(self):
        g = get_config("gemma3-12b")
        full = model_flops(g, SHAPES["decode_32k"])
        # recompute with all-global would be larger
        import dataclasses
        g2 = dataclasses.replace(g, attn_pattern="global")
        assert model_flops(g2, SHAPES["decode_32k"]) > full


class TestSchedules:
    def test_cosine_shape(self):
        lrs = [float(lr_at(s, peak=1.0, total_steps=100, warmup=10)) for s in
               (0, 5, 10, 50, 100)]
        assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
        assert lrs[3] < 1.0 and lrs[4] <= lrs[3]

    def test_wsd_plateau(self):
        lrs = [float(lr_at(s, peak=1.0, total_steps=100, warmup=10, kind="wsd"))
               for s in (10, 40, 80, 100)]
        assert abs(lrs[0] - 1.0) < 1e-6 and abs(lrs[1] - 1.0) < 1e-6
        assert lrs[2] <= 1.0 and lrs[3] < lrs[1]
