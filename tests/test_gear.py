"""GEAR composition tests — the paper's central claims in miniature."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import gear, metrics
from repro.core.policy import CompressionPolicy, named_policy


def _kv_like(key, shape=(4, 256, 128), outlier_p=0.01, outlier_scale=6.0):
    """Heavy-tailed, token-correlated tensor resembling real KV caches."""
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.normal(k1, shape)
    # token correlation (shared low-rank structure across tokens)
    u = jax.random.normal(k2, shape[:-2] + (shape[-2], 8))
    v = jax.random.normal(k3, shape[:-2] + (8, shape[-1]))
    x = base + 1.5 * u @ v
    mask = jax.random.bernoulli(k1, outlier_p, shape)
    return x * (1 + outlier_scale * mask)


def test_error_ordering_matches_paper_fig1a(rng):
    """err(GEAR) < err(GEAR-L) < err(quant-only); outliers help (Table 8)."""
    x = _kv_like(rng)
    errs = {n: float(gear.approx_error(x, named_policy(n), "k"))
            for n in ("kivi2", "outlier_kivi2", "gear_l_kivi2", "gear_kivi2")}
    assert errs["gear_kivi2"] < errs["gear_l_kivi2"] < errs["kivi2"]
    assert errs["outlier_kivi2"] < errs["kivi2"]
    assert errs["gear_kivi2"] < errs["outlier_kivi2"]


def test_gear_4bit_near_lossless(rng):
    x = _kv_like(rng)
    err = float(gear.approx_error(x, named_policy("gear_kcvt4"), "k"))
    assert err < 0.08


def test_decompress_roundtrip_structure(rng):
    x = _kv_like(rng, (2, 64, 64))
    pol = named_policy("gear_kivi2")
    cm = gear.compress_matrix(x, pol, "k")
    xh = gear.decompress_matrix(cm)
    assert xh.shape == x.shape
    assert cm.qt.packed.dtype == jnp.int32
    assert cm.a is not None and cm.sparse is not None
    # compressed strictly smaller than fp16
    assert cm.size_bytes() < x.size * 2


def test_error_reduction_monotone_in_rank(rng):
    x = _kv_like(rng)
    errs = []
    for r in (0, 2, 8):
        pol = CompressionPolicy("gear_l" if r else "quant", "kivi", bits=2, rank=max(r, 1))
        errs.append(float(gear.approx_error(x, pol, "k")))
    assert errs[0] > errs[1] > errs[2]


def test_v_orientation(rng):
    x = _kv_like(rng)
    e_k = float(gear.approx_error(x, named_policy("gear_kivi2"), "k"))
    e_v = float(gear.approx_error(x, named_policy("gear_kivi2"), "v"))
    assert e_k < 0.6 and e_v < 0.6


def test_kv_size_fractions_match_paper_table9():
    """Analytic KV-size within ~1.5% absolute of the paper's Table 9/1."""
    n, d = 1156, 4096  # GSM8k prefill 900 + gen 256
    cases = [
        ("kivi2", 64, 0.217), ("per_token_q4", 64, 0.342),
        ("kcvt4", 20, 0.271), ("gear_l_kivi2", 64, 0.236),
        ("gear_kivi2", 64, 0.276),
    ]
    for name, nb, expect in cases:
        pol = dataclasses.replace(named_policy(name), buffer_size=nb)
        got = metrics.kv_size_fraction(pol, n, d, num_heads=32, head_dim=128)
        assert abs(got - expect) < 0.015, (name, got, expect)


def test_compression_ratio_2bit_beats_4bit():
    pol2 = named_policy("gear_kivi2")
    pol4 = named_policy("gear_kcvt4")
    f2 = metrics.kv_size_fraction(pol2, 4096, 4096, 32, 128)
    f4 = metrics.kv_size_fraction(pol4, 4096, 4096, 32, 128)
    assert f2 < f4 < 0.35
