"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions (assignment requirement f)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_config, shapes_for
from repro.core.policy import FP16, named_policy
from repro.models import transformer as tfm
from repro.models.model import build_model, input_specs

POL = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)


def _batch(cfg, B=2, S=32, key=jax.random.PRNGKey(3)):
    if cfg.modality == "vlm":
        p = cfg.num_prefix_tokens
        return {"tokens": jax.random.randint(key, (B, S - p), 0, cfg.vocab_size),
                "img_embeds": jax.random.normal(key, (B, p, cfg.d_model), jnp.bfloat16)}
    if cfg.modality == "audio":
        return {"tokens": jax.random.randint(key, (B, S, cfg.num_codebooks), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: model.loss_fn(p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch, rng):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, caches = model.prefill(params, batch, POL, 64)
    if cfg.modality == "audio":
        assert logits.shape == (B, 1, cfg.num_codebooks, cfg.vocab_size)
        tok = {"tokens": jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)}
    else:
        assert logits.shape == (B, 1, cfg.vocab_size)
        tok = {"tokens": jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)}
    logits2, caches2 = model.decode_step(params, tok, caches, jnp.asarray(S), POL, 64)
    assert bool(jnp.isfinite(jnp.asarray(logits2, jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ["minicpm-2b", "gemma3-12b", "rwkv6-3b",
                                  "hymba-1.5b", "llama4-scout-17b-a16e"])
def test_decode_matches_full_forward(arch, rng):
    """fp16-cache decode == full forward (MoE at no-drop capacity)."""
    cfg = smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 31
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    logits_full, _ = tfm.forward(cfg, params, {"tokens": toks}, mode="train")
    logits_pf, caches = model.prefill(params, {"tokens": toks[:, :S]}, FP16, 64)
    # activations are bf16: per-element tolerance scales with depth; the
    # decision-relevant check is argmax agreement.
    assert jnp.allclose(logits_pf[:, 0].astype(jnp.float32),
                        logits_full[:, S - 1].astype(jnp.float32), atol=1e-1), arch
    assert (jnp.argmax(logits_pf[:, 0], -1) == jnp.argmax(logits_full[:, S - 1], -1)).all(), arch
    logits_dec, _ = model.decode_step(params, {"tokens": toks[:, S:]}, caches,
                                      jnp.asarray(S), FP16, 64)
    assert jnp.allclose(logits_dec[:, 0].astype(jnp.float32),
                        logits_full[:, S].astype(jnp.float32), atol=2e-1), arch
    agree = (jnp.argmax(logits_dec[:, 0], -1) == jnp.argmax(logits_full[:, S], -1)).mean()
    assert agree >= 0.5, (arch, float(agree))


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned hyper-params."""
    expect = {
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff if not c.moe else c.moe_d_ff, c.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("qwen3-moe-235b-a22b").num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe_top_k == 8
    assert get_config("llama4-scout-17b-a16e").num_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe_top_k == 1
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("rwkv6-3b").rwkv


def test_input_specs_cover_all_cells():
    from repro.configs import SHAPES
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(arch):
            specs = input_specs(cfg, shape)
            assert all(hasattr(s, "shape") for s in jax.tree.leaves(specs))
