"""End-to-end behaviour tests: serving engine, scheduler, GEAR-vs-FP16 logit
fidelity, data pipeline determinism, checkpoint atomicity."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.policy import FP16, named_policy
from repro.data.synthetic import DataConfig, make_batch
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Scheduler, Request
import repro.ckpt.checkpoint as ck


@pytest.fixture(scope="module")
def dense_model():
    cfg = smoke_config("minicpm-2b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_generate_shapes_and_determinism(dense_model):
    cfg, m, params = dense_model
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)
    eng = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=pol))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)}
    t1, _ = eng.generate(batch, 12)
    t2, _ = eng.generate(batch, 12)
    assert t1.shape == (2, 12)
    assert (t1 == t2).all()  # greedy decode is deterministic


def test_gear_vs_fp16_generation_close(dense_model):
    """4-bit GEAR generation tracks FP16 generation for many steps —
    the error-compounding claim (paper Fig 1b) at small scale."""
    cfg, m, params = dense_model
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0, cfg.vocab_size)}
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)
    eng_g = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=pol))
    eng_f = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=FP16))
    tg, _ = eng_g.generate(batch, 10)
    tf, _ = eng_f.generate(batch, 10)
    agree = float((tg == tf).mean())
    assert agree >= 0.5, f"4-bit GEAR diverged too fast: agreement {agree}"


def test_gear_cache_smaller_than_fp16(dense_model):
    cfg, m, params = dense_model
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0, cfg.vocab_size)}
    pol = dataclasses.replace(named_policy("gear_kivi2"), buffer_size=16, group=16)
    eng_g = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=pol))
    eng_f = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=FP16))
    _, sg = eng_g.generate(batch, 4)
    _, sf = eng_f.generate(batch, 4)
    # packed int32 carriers count 4 bytes; the bit-level size is what the
    # metrics module reports — structural check only here.
    assert sg["cache_bytes"] < sf["cache_bytes"]


def test_scheduler_drains_queue(dense_model):
    cfg, m, params = dense_model
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)
    eng = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=pol))
    sched = Scheduler(eng)
    for i in range(3):
        sched.submit(Request(rid=i, tokens=np.arange(5 + i) % cfg.vocab_size,
                             max_new_tokens=6))
    res = sched.run()
    assert sorted(r.rid for r in res) == [0, 1, 2]
    assert all(r.tokens.shape == (6,) for r in res)


def test_data_pipeline_deterministic():
    cfg = smoke_config("minicpm-2b")
    dc = DataConfig(seed=7, vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    b1 = make_batch(dc, cfg, 5)
    b2 = make_batch(dc, cfg, 5)
    b3 = make_batch(dc, cfg, 6)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert not (b1["tokens"] == b3["tokens"]).all()
    assert int(b1["tokens"].max()) < cfg.vocab_size


def test_checkpoint_atomic_and_corruption_detected(tmp_path):
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    d = ck.save(str(tmp_path), 1, tree)
    assert os.path.exists(os.path.join(d, "_COMMITTED"))
    assert ck.latest_step(str(tmp_path)) == 1
    restored = ck.restore(str(tmp_path), 1, tree)
    assert (restored["a"] == tree["a"]).all()
    # corrupt a leaf -> restore must fail loudly
    np.save(os.path.join(d, "arr_0.npy"), np.arange(10) + 1)
    with pytest.raises(IOError):
        ck.restore(str(tmp_path), 1, tree)


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.arange(4)}
    d = ck.save(str(tmp_path), 3, tree)
    os.remove(os.path.join(d, "_COMMITTED"))
    assert ck.latest_step(str(tmp_path)) is None
