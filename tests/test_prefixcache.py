"""Radix-trie prefix cache: trie invariants, chunk extract/splice round
trips, and the archetype guarantee — splice-from-cache ≡ recompute-from-
scratch, bit for bit (caches, logits, and greedy decode tokens).

The trie tests exercise the structure standalone (longest-match
correctness, LRU eviction under a byte budget, refcount pinning), plus a
hypothesis property over arbitrary interleavings of insert / lookup /
acquire / release.  The engine tests pin that a warm request sharing a
>= 2-chunk prefix is indistinguishable from a cold run — GEAR's
chunk-independent, slot-invariant compression is what makes the cache
lossless (DESIGN.md §4).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.prefixcache import PrefixCache, RadixTrie
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Request, Scheduler

GEAR_POL = dataclasses.replace(named_policy("gear_kcvt4"),
                               buffer_size=8, rank=2, rank_decode=2)
TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                   vocab_size=64)
NB = GEAR_POL.buffer_size
EOS = 3
PROMPT_PAD = 32


# ---------------------------------------------------------------------------
# Trie unit tests


def _keys(*tokens_per_chunk):
    return [tuple(c) for c in tokens_per_chunk]


def _entry(nbytes=10, handle=None):
    return (object() if handle is None else handle, nbytes)


def test_trie_longest_match_and_stats():
    trie = RadixTrie(budget_bytes=1 << 20)
    path = _keys([1, 2], [3, 4], [5, 6])
    trie.insert(path, [_entry() for _ in path])
    assert len(trie.lookup(path)) == 3
    assert len(trie.lookup(path[:2])) == 2
    assert len(trie.lookup(_keys([1, 2], [9, 9]))) == 1   # diverges at chunk 1
    assert trie.lookup(_keys([7, 7])) == []
    st = trie.stats
    assert (st.lookups, st.hits, st.misses) == (4, 3, 1)
    assert st.hit_chunks == 6 and st.lookup_chunks == 8
    assert st.prefix_hit_rate == pytest.approx(6 / 8)


def test_trie_shared_prefix_not_duplicated():
    trie = RadixTrie(budget_bytes=1 << 20)
    trie.insert(_keys([1], [2]), [_entry(), _entry()])
    created, unused, _ = trie.insert(_keys([1], [3]),
                                     [_entry(handle="dup"), _entry()])
    assert len(created) == 1 and unused == ["dup"]   # chunk [1] already cached
    assert trie.n_nodes == 3


def test_trie_insert_past_missing_node_returns_orphan_handles():
    """Entries after an un-backed gap are handed back, never leaked."""
    trie = RadixTrie(budget_bytes=1 << 20)
    created, unused, _ = trie.insert(
        _keys([1], [2], [3]), [None, _entry(handle="x"), _entry(handle="y")])
    assert created == [] and unused == ["x", "y"] and trie.n_nodes == 0


def test_trie_lru_eviction_order_and_budget():
    trie = RadixTrie(budget_bytes=20)                 # fits two 10-byte chunks
    trie.insert(_keys([1]), [_entry(handle="a")])
    trie.insert(_keys([2]), [_entry(handle="b")])
    trie.lookup(_keys([1]))                           # bump "a": "b" is now LRU
    _, _, evicted = trie.insert(_keys([3]), [_entry(handle="c")])
    assert evicted == ["b"]
    assert trie.total_bytes <= trie.budget_bytes
    assert len(trie.lookup(_keys([1]))) == 1 and len(trie.lookup(_keys([3]))) == 1


def test_trie_interior_nodes_survive_leaf_eviction():
    """A node with children is never evicted before its descendants."""
    trie = RadixTrie(budget_bytes=1 << 20)
    trie.insert(_keys([1], [2], [3]), [_entry(10, h) for h in "abc"])
    trie.budget_bytes = 15                            # must drop to one node
    evicted = trie.evict_to_budget()
    assert evicted == ["c", "b"]                      # deepest-first, never "a" first
    assert len(trie.lookup(_keys([1], [2], [3]))) == 1


def test_trie_ttl_expiry_prunes_lazily():
    clock = {"t": 0.0}
    trie = RadixTrie(1 << 20, ttl=10.0, clock=lambda: clock["t"])
    trie.insert(_keys([1], [2]), [_entry(10, "a"), _entry(10, "b")])
    clock["t"] = 9.0
    assert len(trie.lookup(_keys([1], [2]))) == 2     # still fresh
    clock["t"] = 10.5                                 # hits did NOT refresh
    assert trie.lookup(_keys([1], [2])) == []
    assert trie.n_nodes == 0 and trie.total_bytes == 0
    assert trie.stats.expiries == 2
    assert set(trie.drain_pruned()) == {"a", "b"}
    assert trie.drain_pruned() == []                  # drained once


def test_trie_version_bump_invalidates_everything():
    trie = RadixTrie(1 << 20)
    trie.insert(_keys([1], [2]), [_entry(10, "a"), _entry(10, "b")])
    trie.bump_version()
    assert trie.lookup(_keys([1], [2])) == []
    assert trie.stats.version_evictions == 2
    assert set(trie.drain_pruned()) == {"a", "b"}
    # inserts under the new version are live again
    trie.insert(_keys([1]), [_entry(10, "c")])
    assert len(trie.lookup(_keys([1]))) == 1
    assert trie.n_nodes == 1


def test_trie_stale_pinned_subtree_blocks_without_leaking():
    """A stale-but-pinned subtree defers pruning: walks stop at it (no
    match, no overwrite — handles of a colliding insert come back as
    unused) and the prune happens on the first walk after release."""
    clock = {"t": 0.0}
    trie = RadixTrie(1 << 20, ttl=5.0, clock=lambda: clock["t"])
    trie.insert(_keys([1], [2]), [_entry(10, "a"), _entry(10, "b")])
    pinned = trie.lookup(_keys([1], [2]), acquire=True)
    clock["t"] = 6.0
    assert trie.lookup(_keys([1], [2])) == []         # stale: never matches
    created, unused, _ = trie.insert(_keys([1], [3]),
                                     [_entry(10, "x"), _entry(10, "y")])
    assert created == [] and set(unused) == {"x", "y"}
    assert trie.n_nodes == 2 and trie.drain_pruned() == []
    trie.release(pinned)
    assert trie.lookup(_keys([1])) == []              # now prunable
    assert set(trie.drain_pruned()) == {"a", "b"}
    assert trie.stats.expiries == 2 and trie.n_nodes == 0


def test_trie_lfu_evicts_least_used_not_least_recent():
    """a: hot early (3 uses, oldest recency).  b: cold (1 use, newer
    recency).  LRU would sacrifice a; LFU keeps it and drops b.  The
    incoming chunk c ties b on uses but is newer, so it is admitted."""
    trie = RadixTrie(budget_bytes=20, eviction="lfu")
    trie.insert(_keys([1]), [_entry(10, "a")])
    trie.lookup(_keys([1]))
    trie.lookup(_keys([1]))                           # a: 3 uses, oldest
    trie.insert(_keys([2]), [_entry(10, "b")])        # b: 1 use, most recent
    _, _, evicted = trie.insert(_keys([3]), [_entry(10, "c")])
    assert evicted == ["b"]                           # LRU would pick "a"
    assert len(trie.lookup(_keys([1]))) == 1
    assert len(trie.lookup(_keys([3]))) == 1


def test_trie_rejects_unknown_eviction_policy():
    with pytest.raises(ValueError, match="eviction"):
        RadixTrie(1 << 20, eviction="mru")


def test_prefix_cache_ttl_frees_store_payloads():
    clock = {"t": 0.0}
    pc = PrefixCache(chunk=2, budget_bytes=1 << 20, ttl=4.0,
                     eviction="lfu", clock=lambda: clock["t"])
    pc.insert([1, 2, 3, 4], [np.zeros(4, np.uint8), np.zeros(4, np.uint8)])
    m = pc.match([1, 2, 3, 4])
    pc.release(m)
    assert m.n_chunks == 2
    clock["t"] = 5.0
    m = pc.match([1, 2, 3, 4])
    pc.release(m)
    assert m.n_chunks == 0
    st = pc.stats
    assert st["expiries"] == 2 and st["nodes"] == 0 and st["bytes"] == 0
    assert len(pc.store) == 0 and pc.store.total_bytes == 0


def test_trie_refcounted_nodes_never_evicted():
    trie = RadixTrie(budget_bytes=1 << 20)
    trie.insert(_keys([1], [2]), [_entry(10, "a"), _entry(10, "b")])
    pinned = trie.lookup(_keys([1], [2]), acquire=True)
    trie.budget_bytes = 0
    assert trie.evict_to_budget() == []               # everything pinned
    assert trie.total_bytes == 20                     # soft bound while pinned
    trie.release(pinned)
    assert set(trie.evict_to_budget()) == {"a", "b"}
    assert trie.total_bytes == 0
    with pytest.raises(ValueError):
        trie.release(pinned)                          # double release


# ---------------------------------------------------------------------------
# Hypothesis property: arbitrary interleavings preserve the invariants


def _facade_invariants(pc: PrefixCache, held):
    trie = pc.trie
    # byte/node accounting: trie totals == walked totals == store totals
    walked_bytes, walked_nodes = 0, 0
    stack = list(trie.root.children.values())
    while stack:
        nd = stack.pop()
        walked_bytes += nd.nbytes
        walked_nodes += 1
        stack.extend(nd.children.values())
    assert trie.total_bytes == walked_bytes == pc.store.total_bytes
    assert trie.n_nodes == walked_nodes == len(pc.store)
    # every pinned node is still attached (never evicted while referenced)
    for match in held:
        for nd in match.nodes:
            assert nd.parent.children.get(nd.key) is nd
    # budget is a hard bound whenever nothing is pinned
    if not held:
        assert trie.total_bytes <= trie.budget_bytes


def _maximal_match(pc: PrefixCache, tokens):
    from repro.prefixcache import chunk_keys
    keys = chunk_keys(tokens, pc.chunk)
    path = pc.trie.lookup(keys)
    # longest-match: the path matches the query and cannot be extended
    for nd, key in zip(path, keys):
        assert nd.key == key
    if len(path) < len(keys):
        tip = path[-1] if path else pc.trie.root
        assert keys[len(path)] not in tip.children


def test_trie_property_interleavings():
    pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed; CI's full lane installs it via "
               "`pip install -e .[test]`")
    from hypothesis import given, settings, strategies as st, HealthCheck

    chunk = 2
    tokens_strat = st.lists(st.integers(0, 2), min_size=0, max_size=10)
    op = st.one_of(
        st.tuples(st.just("insert"), tokens_strat, st.integers(1, 40)),
        st.tuples(st.just("lookup"), tokens_strat, st.just(0)),
        st.tuples(st.just("acquire"), tokens_strat, st.just(0)),
        st.tuples(st.just("release"), st.just(None), st.integers(0, 5)),
    )

    @given(budget=st.integers(0, 200), ops=st.lists(op, max_size=40))
    @settings(max_examples=50, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def run(budget, ops):
        pc = PrefixCache(chunk=chunk, budget_bytes=budget)
        held = []
        for kind, tokens, arg in ops:
            if kind == "insert":
                n_full = len(tokens) // chunk
                payloads = [np.zeros(arg, np.uint8) for _ in range(n_full)]
                pc.insert(tokens, payloads)
            elif kind == "lookup":
                _maximal_match(pc, tokens)
            elif kind == "acquire":
                held.append(pc.match(tokens))
            elif kind == "release" and held:
                pc.release(held.pop(arg % len(held)))
            _facade_invariants(pc, held)
        while held:
            pc.release(held.pop())
        pc.trie.evict_to_budget()
        _facade_invariants(pc, held)
        assert pc.trie.total_bytes <= budget

    run()


# ---------------------------------------------------------------------------
# Chunk extract/splice round trip (core APIs)


@pytest.mark.parametrize("policy_name", ["gear_kcvt4", "gear_kivi2", "kcvt4"])
def test_extract_splice_roundtrip(policy_name):
    """extract_prefix_chunks -> splice_prefix_chunks reproduces the chunk
    rows of the source cache exactly, into any slot of a wider cache."""
    pol = dataclasses.replace(named_policy(policy_name), buffer_size=8,
                              rank=2, rank_decode=2,
                              group=4 if "kivi" in policy_name else 64)
    cfg = cache_lib.CacheConfig(batch=1, kv_heads=2, head_dim=16,
                                capacity=32, policy=pol)
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (1, 2, 24, 16))
    v = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 24, 16))
    src = cache_lib.prefill_layer_cache(cfg, cache_lib.init_layer_cache(cfg), k, v)

    chunks = cache_lib.extract_prefix_chunks(cfg, src, 2)
    cfg3 = dataclasses.replace(cfg, batch=3)
    dst = cache_lib.splice_prefix_chunks(
        cfg3, cache_lib.init_layer_cache(cfg3), 2, chunks)
    spec = cache_lib._chunk_row_axes(cfg)
    for field, (rpc, ax) in spec.items():
        a = np.asarray(getattr(src, field))
        b = np.asarray(getattr(dst, field))[2:3]
        sl = [slice(None)] * a.ndim
        sl[a.ndim + ax] = slice(0, 2 * rpc)
        np.testing.assert_array_equal(a[tuple(sl)], b[tuple(sl)], err_msg=field)


# ---------------------------------------------------------------------------
# Engine integration: warm ≡ cold, bit for bit


_ENGINES: dict = {}


def _engines():
    """(cold, warm, warm-tiny-budget) engines over shared tiny params."""
    if not _ENGINES:
        model = build_model(TINY)
        params = model.init(jax.random.PRNGKey(0))
        base = EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                            prefill_mode="streaming", eos_id=EOS)
        _ENGINES["model"] = (model, params)
        _ENGINES["cold"] = Engine(model, params, base)
        _ENGINES["warm"] = Engine(model, params,
                                  dataclasses.replace(base, prefix_cache=True))
    return _ENGINES["cold"], _ENGINES["warm"]


def _prompts(shared_chunks=3, n=2, seed=0):
    rng = np.random.RandomState(seed)
    shared = rng.randint(4, TINY.vocab_size, size=shared_chunks * NB)
    return [np.concatenate([shared,
                            rng.randint(4, TINY.vocab_size, size=PROMPT_PAD
                                        - shared.size)])
            for _ in range(n)]


def _slot_leaves(caches, slot):
    return [np.asarray(x)[:, slot] for x in jax.tree.leaves(caches)]


def test_warm_prefill_bit_identical_to_cold():
    """The acceptance criterion: a second request sharing a >= 2-chunk
    prefix produces bit-identical per-slot caches and logits vs cold."""
    cold, warm = _engines()
    pa, pb = _prompts(shared_chunks=3)
    cc, wc = cold.init_caches(), warm.init_caches()
    for slot, prompt in ((0, pa), (1, pb)):
        batch1 = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
        lc, cc = cold.prefill_slot(batch1, cc, slot)
        lw, wc = warm.prefill_slot(batch1, wc, slot)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lw))
        for a, b in zip(_slot_leaves(cc, slot), _slot_leaves(wc, slot)):
            np.testing.assert_array_equal(a, b)
    st = warm.prefix_cache.stats
    assert st["hit_chunks"] == 3 and st["prefill_toks_saved"] == 3 * NB
    assert st["bytes"] <= warm.ecfg.prefix_cache_bytes


def test_warm_hit_extends_cached_path():
    """A third request reusing the longest prompt hits its full eligible
    prefix (the earlier requests' suffix chunks were inserted too)."""
    _, warm = _engines()
    (pa,) = _prompts(shared_chunks=3, n=1, seed=7)
    wc = warm.init_caches()
    batch1 = {"tokens": jnp.asarray(pa[None], jnp.int32)}
    before = warm.prefix_cache.stats["hit_chunks"]
    _, wc = warm.prefill_slot(batch1, wc, 0)
    _, wc = warm.prefill_slot(batch1, wc, 1)
    # identical prompt: second pass hits every eligible chunk (all but the
    # one that must stay suffix so prefill still emits last-token logits)
    assert (warm.prefix_cache.stats["hit_chunks"] - before
            >= (PROMPT_PAD - 1) // NB)


def test_continuous_batching_prefix_on_off_token_parity():
    """Greedy continuous batching returns identical tokens with the prefix
    cache on and off, and reports hit-rate/saved-token stats."""
    cold, warm = _engines()
    outs = {}
    for name, eng in (("off", cold), ("on", warm)):
        sched = Scheduler(eng)
        for i, prompt in enumerate(_prompts(shared_chunks=3, n=4, seed=1)):
            sched.submit(Request(rid=i, tokens=prompt, max_new_tokens=5))
        outs[name] = {r.rid: r.tokens for r in sched.run_continuous()}
        if name == "on":
            assert sched.last_stats["prefix_hit_rate"] > 0
            assert sched.last_stats["prefill_toks_saved"] > 0
    assert sorted(outs["off"]) == sorted(outs["on"])
    for rid in outs["off"]:
        np.testing.assert_array_equal(outs["off"][rid], outs["on"][rid])
    # last_stats is per-run, not engine-lifetime: replaying the workload
    # hits every eligible chunk, so THIS run's rate is exactly 1.0
    sched = Scheduler(warm)
    for i, prompt in enumerate(_prompts(shared_chunks=3, n=4, seed=1)):
        sched.submit(Request(rid=i, tokens=prompt, max_new_tokens=5))
    sched.run_continuous()
    assert sched.last_stats["prefix_hit_rate"] == 1.0
    assert (sched.last_stats["prefill_toks_saved"]
            == 4 * ((PROMPT_PAD - 1) // NB) * NB)


def test_admission_off_reuses_but_never_inserts():
    _engines()
    model, params = _ENGINES["model"]
    eng = Engine(model, params,
                 EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                              prefill_mode="streaming", eos_id=EOS,
                              prefix_cache=True))
    sched = Scheduler(eng, prefix_admission="off")
    for i, prompt in enumerate(_prompts(shared_chunks=3, n=3, seed=2)):
        sched.submit(Request(rid=i, tokens=prompt, max_new_tokens=2))
    sched.run_continuous()
    st = eng.prefix_cache.stats
    assert st["inserts"] == 0 and st["hit_chunks"] == 0
    assert sched.last_stats["prefix_hit_rate"] == 0.0


def test_engine_eviction_respects_byte_budget():
    """A tiny budget keeps the store within bounds while serving stays
    correct (warm results still match the unbounded-warm engine)."""
    _engines()
    model, params = _ENGINES["model"]
    # budget for about two chunks of payload
    probe = Engine(model, params,
                   EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                                prefill_mode="streaming", eos_id=EOS,
                                prefix_cache=True))
    pa = _prompts(shared_chunks=3, n=1, seed=3)[0]
    wc = probe.init_caches()
    _, wc = probe.prefill_slot({"tokens": jnp.asarray(pa[None], jnp.int32)}, wc, 0)
    per_chunk = probe.prefix_cache.stats["bytes"] // max(
        probe.prefix_cache.stats["nodes"], 1)

    small = Engine(model, params,
                   EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                                prefill_mode="streaming", eos_id=EOS,
                                prefix_cache=True,
                                prefix_cache_bytes=2 * per_chunk))
    sched = Scheduler(small)
    prompts = _prompts(shared_chunks=1, n=5, seed=4)
    for i, prompt in enumerate(prompts):
        sched.submit(Request(rid=i, tokens=prompt, max_new_tokens=2))
    out = sched.run_continuous()
    assert len(out) == len(prompts)
    st = small.prefix_cache.stats
    assert st["evictions"] > 0
    assert st["bytes"] <= small.ecfg.prefix_cache_bytes
    assert small.prefix_cache.store.total_bytes == st["bytes"]


@pytest.mark.kernel
def test_warm_equals_cold_through_interpret_kernels():
    """Warm ≡ cold holds on the forced Pallas-kernel path too (interpret
    mode on CPU): the suffix pipeline's gear_compress / gear_decode /
    flash_prefill_block kernels see prefix-cache shapes in CI."""
    _engines()
    model, params = _ENGINES["model"]
    ecfg = EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                        prefill_mode="streaming", eos_id=EOS,
                        fused="interpret")
    cold = Engine(model, params, ecfg)
    warm = Engine(model, params, dataclasses.replace(ecfg, prefix_cache=True))
    pa, pb = _prompts(shared_chunks=2, n=2, seed=5)
    cc, wc = cold.init_caches(), warm.init_caches()
    for slot, prompt in ((0, pa), (1, pb)):
        batch1 = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
        lc, cc = cold.prefill_slot(batch1, cc, slot)
        lw, wc = warm.prefill_slot(batch1, wc, slot)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lw))
        for a, b in zip(_slot_leaves(cc, slot), _slot_leaves(wc, slot)):
            np.testing.assert_array_equal(a, b)
    assert warm.prefix_cache.stats["hit_chunks"] == 2


def test_prefix_cache_config_validation():
    with pytest.raises(ValueError, match="streaming"):
        EngineConfig(batch=1, capacity=64, policy=GEAR_POL, prefix_cache=True)
    _engines()
    model, params = _ENGINES["model"]
    with pytest.raises(ValueError, match="prefix_cache unsupported"):
        Engine(model, params,
               EngineConfig(batch=1, capacity=64, policy=FP16,
                            prefill_mode="streaming", prefix_cache=True))
    win = dataclasses.replace(TINY, attn_pattern="local_global",
                              pattern_locals=1, local_window=8)
    wmodel = build_model(win)
    with pytest.raises(ValueError, match="prefix_cache unsupported"):
        Engine(wmodel, wmodel.init(jax.random.PRNGKey(0)),
               EngineConfig(batch=1, capacity=64, policy=GEAR_POL,
                            prefill_mode="streaming", prefix_cache=True))


def test_lifecycle_knob_validation():
    with pytest.raises(ValueError, match="prefix_cache_eviction"):
        EngineConfig(batch=1, capacity=64, policy=GEAR_POL,
                     prefill_mode="streaming", prefix_cache=True,
                     prefix_cache_eviction="mru")
    with pytest.raises(ValueError, match="prefix_cache_ttl"):
        EngineConfig(batch=1, capacity=64, policy=GEAR_POL,
                     prefill_mode="streaming", prefix_cache=True,
                     prefix_cache_ttl=-1.0)
    with pytest.raises(ValueError, match="require prefix_cache"):
        EngineConfig(batch=1, capacity=64, policy=GEAR_POL,
                     prefill_mode="streaming", prefix_cache_ttl=5.0)


def test_engine_set_params_invalidates_prefix_cache():
    """Swapping weights bumps the engine's weight version; chunks cached
    under the old version are pruned on the next walk, never reused."""
    _engines()
    model, params = _ENGINES["model"]
    eng = Engine(model, params,
                 EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                              prefill_mode="streaming", eos_id=EOS,
                              prefix_cache=True))
    (pa,) = _prompts(shared_chunks=3, n=1, seed=9)
    batch1 = {"tokens": jnp.asarray(pa[None], jnp.int32)}
    wc = eng.init_caches()
    _, wc = eng.prefill_slot(batch1, wc, 0)
    assert eng.prefix_cache.stats["nodes"] > 0
    v0 = eng.weight_version
    eng.set_params(params)                   # same values, new version
    assert eng.weight_version == v0 + 1
    _, wc = eng.prefill_slot(batch1, wc, 1)  # must NOT reuse stale chunks
    st = eng.prefix_cache.stats
    assert st["version_evictions"] > 0 and st["hit_chunks"] == 0
    assert st["nodes"] > 0                   # re-admitted under new version
    assert eng.prefix_cache.store.total_bytes == st["bytes"]


def test_engine_ttl_expires_chunks_between_requests():
    """With a TTL, a warm request arriving after expiry recomputes from
    scratch — and still matches a cold engine bit for bit."""
    _engines()
    model, params = _ENGINES["model"]
    clock = {"t": 0.0}
    cold, _ = _engines()
    eng = Engine(model, params,
                 EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                              prefill_mode="streaming", eos_id=EOS,
                              prefix_cache=True, prefix_cache_ttl=30.0))
    eng.prefix_cache.trie.clock = lambda: clock["t"]
    (pa,) = _prompts(shared_chunks=3, n=1, seed=10)
    batch1 = {"tokens": jnp.asarray(pa[None], jnp.int32)}
    cc, wc = cold.init_caches(), eng.init_caches()
    lc, cc = cold.prefill_slot(batch1, cc, 0)
    _, wc = eng.prefill_slot(batch1, wc, 0)
    clock["t"] = 31.0                        # everything cached is now stale
    lw, wc = eng.prefill_slot(batch1, wc, 1)
    st = eng.prefix_cache.stats
    assert st["expiries"] > 0 and st["hit_chunks"] == 0
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lw))
    for a, b in zip(_slot_leaves(cc, 0), _slot_leaves(wc, 1)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_mixed_length_warm_equals_cold_bitwise(layout):
    """The tentpole guarantee: two RAW requests of different, unaligned
    lengths sharing a 2-chunk system prompt — the warm engine splices the
    shared chunks and length-buckets each suffix, yet logits (and, dense,
    the whole per-slot cache) stay bit-identical to a cold engine that
    never saw the other request.

    Both raw lengths sit in ONE length bucket: chunk bits are only
    guaranteed reproducible within a jit program shape (XLA codegen is
    per-shape), so bitwise parity requires the trie's seeding request and
    the cold reference to share a bucket — cross-bucket reuse is
    near-lossless, not bit-exact (DESIGN.md §4)."""
    _engines()
    model, params = _ENGINES["model"]
    base = EngineConfig(batch=2, capacity=64, policy=GEAR_POL,
                        prefill_mode="streaming", eos_id=EOS, layout=layout)
    cold = Engine(model, params, base)
    warm = Engine(model, params, dataclasses.replace(base, prefix_cache=True))
    rng = np.random.RandomState(11)
    shared = rng.randint(4, TINY.vocab_size, size=2 * NB)
    prompts = [np.concatenate([shared, rng.randint(4, TINY.vocab_size, size=3)]),
               np.concatenate([shared, rng.randint(4, TINY.vocab_size, size=6)])]
    assert len({len(p) for p in prompts}) == 2          # genuinely mixed
    assert all(len(p) % NB for p in prompts)            # unaligned suffixes
    assert len({-(-len(p) // NB) for p in prompts}) == 1    # same bucket
    cc, wc = cold.init_caches(), warm.init_caches()
    for slot, prompt in enumerate(prompts):
        batch1 = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
        lc, cc = cold.prefill_slot(batch1, cc, slot)
        lw, wc = warm.prefill_slot(batch1, wc, slot)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lw))
        if layout == "dense":
            for a, b in zip(_slot_leaves(cc, slot), _slot_leaves(wc, slot)):
                np.testing.assert_array_equal(a, b)
    # the second request hit exactly the shared chunks, nothing more
    assert warm.prefix_cache.stats["hit_chunks"] == 2
    assert warm.prefix_cache.stats["prefill_toks_saved"] == 2 * NB


def test_mixed_length_fallback_policy_serves_at_exact_length():
    """kivi2 with group != chunk has no streaming layout, so the engine
    cannot length-bucket; mixed raw-length prompts still serve (one exact-
    length prefill program each) and match a monolithic engine bit for
    bit through continuous batching."""
    _engines()
    model, params = _ENGINES["model"]
    pol = dataclasses.replace(named_policy("gear_kivi2"), buffer_size=8,
                              group=4, rank=2, rank_decode=2)
    outs = {}
    for mode in ("monolithic", "streaming"):
        eng = Engine(model, params,
                     EngineConfig(batch=2, capacity=64, policy=pol,
                                  eos_id=EOS, prefill_mode=mode))
        assert not eng._can_bucket
        sched = Scheduler(eng)
        rng = np.random.RandomState(3)
        for i, n in enumerate((13, 21)):
            sched.submit(Request(rid=i,
                                 tokens=rng.randint(4, TINY.vocab_size, size=n),
                                 max_new_tokens=4))
        outs[mode] = {r.rid: r.tokens for r in sched.run_continuous()}
    for rid in outs["monolithic"]:
        np.testing.assert_array_equal(outs["monolithic"][rid],
                                      outs["streaming"][rid])
