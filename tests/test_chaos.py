"""Chaos suite: seeded fault injection against the serving resilience layer.

The contract under test (ISSUE 9, docs/serving.md §4): under ANY seeded
fault schedule — forced pool exhaustion, NaN-poisoned chunks, engine-step
exceptions, clock skew, mid-flight trie eviction —

* the scheduler never crashes;
* every submitted rid terminates with exactly one typed :class:`Result`;
* :meth:`PagePool.audit` reports zero leaked pages / refcount drift;
* requests the faults did not touch (``OK`` / ``DEGRADED`` statuses)
  produce tokens bit-identical to a fault-free run of the same workload.

Runs in the dedicated CI chaos lane (``pytest -m chaos``) and inside the
full tier-1 suite.  The hypothesis property is the satellite's random
schedule sweep; the seeded parametrized twin keeps coverage when
hypothesis is not installed.
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core.policy import named_policy
from repro.models.model import build_model
from repro.prefixcache import PrefixCache
from repro.serving import (AdmissionValve, Engine, EngineConfig, FakeClock,
                           FaultEvent, FaultInjector, PagePool, Request,
                           RequestStatus, RetryPolicy, Scheduler)

pytestmark = pytest.mark.chaos

EOS = 3
CAP = 48

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                   vocab_size=64)


def _small(name="gear_kcvt4"):
    pol = named_policy(name)
    return dataclasses.replace(pol, buffer_size=8, group=min(pol.group, 8),
                               rank=2, rank_decode=2)


_MODELS: dict = {}


def _model(cfg):
    if cfg.name not in _MODELS:
        m = build_model(cfg)
        _MODELS[cfg.name] = (m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[cfg.name]


_ENGINES: dict = {}


def _engine(key="paged", **over):
    """Shared engines (jit programs are the expensive part) keyed by config.
    Callers must detach/attach their own injector via the Scheduler."""
    if key not in _ENGINES:
        kw = dict(batch=2, capacity=CAP, policy=_small(), eos_id=EOS,
                  layout="paged")
        kw.update(over)
        clock = kw.pop("clock", None)
        m, params = _model(TINY)
        _ENGINES[key] = Engine(m, params, EngineConfig(**kw), clock=clock)
    return _ENGINES[key]


def _requests(n=5, seed=0, deadline_s=None):
    rng = np.random.RandomState(seed)
    budgets = [6, 3, 9, 1, 5, 7, 2][:n]
    return [Request(rid=i,
                    tokens=rng.randint(4, 64, size=rng.randint(2, 9)),
                    max_new_tokens=b, deadline_s=deadline_s)
            for i, b in enumerate(budgets)]


def _drive(engine, faults=None, retry=None, valve=None, clock=None, reqs=None):
    engine.attach_faults(None)          # drop any injector a prior run wired
    sched = Scheduler(engine,
                      retry=retry or RetryPolicy(max_attempts=2),
                      valve=valve, faults=faults, clock=clock)
    for r in (reqs if reqs is not None else _requests()):
        sched.submit(r)
    results = sched.run_continuous()
    return sched, results


def _by_rid(results):
    return {r.rid: r for r in results}


# ---------------------------------------------------------------------------
# Fault-free lifecycle: typed statuses + audit on the happy path


def test_faultfree_all_ok_and_audit_clean():
    sched, results = _drive(_engine())
    assert [r.status for r in results].count(RequestStatus.OK) == len(results)
    assert all(r.attempts == 1 for r in results)
    assert sched.last_stats["statuses"] == {"ok": len(results)}
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]


# ---------------------------------------------------------------------------
# Satellite: bounded retries — sustained pool pressure ends in REJECTED,
# never a livelock (the old path requeued forever)


def test_injected_pool_exhaustion_bounds_retries():
    clk = FakeClock()
    inj = FaultInjector(seed=0, rates={"pool_exhausted": 1.0}, clock=clk)
    sched, results = _drive(
        _engine(), faults=inj,
        retry=RetryPolicy(max_attempts=3, backoff_s=0.1))
    assert len(results) == 5
    for r in results:
        assert r.status is RequestStatus.REJECTED
        assert r.attempts == 3          # capped, not infinite
        assert r.tokens.size == 0
    assert clk.now() > 0.0              # backoff waits ran on the fake clock
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]


def test_transient_pool_pressure_completes_degraded():
    """One forced exhaustion on the first admit: the request retries,
    completes, and carries DEGRADED with bit-identical tokens."""
    _, clean = _drive(_engine())
    inj = FaultInjector(seed=0, schedule=[FaultEvent("pool_exhausted", 0)],
                        clock=FakeClock())
    sched, results = _drive(_engine(), faults=inj,
                            retry=RetryPolicy(max_attempts=3))
    got = _by_rid(results)
    assert got[0].status is RequestStatus.DEGRADED
    assert got[0].attempts == 2
    for rid, r in _by_rid(clean).items():
        np.testing.assert_array_equal(got[rid].tokens, r.tokens)
    assert sched.audit(results)["ok"]


# ---------------------------------------------------------------------------
# Numeric quarantine: a poisoned chunk fails ONE request; co-batched slots
# are bit-identical to the fault-free run


def test_nan_quarantine_isolates_one_request():
    _, clean = _drive(_engine())
    inj = FaultInjector(seed=0, schedule=[FaultEvent("nan_chunk", 1)])
    sched, results = _drive(_engine(), faults=inj)
    got = _by_rid(results)
    assert got[1].status is RequestStatus.FAILED
    assert "quarantine" in got[1].error
    assert got[1].tokens.size == 0
    for rid, r in _by_rid(clean).items():
        if rid == 1:
            continue
        assert got[rid].status is RequestStatus.OK
        np.testing.assert_array_equal(got[rid].tokens, r.tokens)
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]     # the rolled-back pages did not leak


def test_numeric_guard_off_lets_nan_through():
    """The knob is real: with numeric_guard=False the poisoned request is
    not quarantined (it completes, garbage in its own slot only)."""
    eng = _engine("paged_noguard", numeric_guard=False)
    inj = FaultInjector(seed=0, schedule=[FaultEvent("nan_chunk", 1)])
    sched, results = _drive(eng, faults=inj)
    assert _by_rid(results)[1].status is RequestStatus.OK
    assert sched.audit(results)["ok"]


# ---------------------------------------------------------------------------
# Engine-step faults: bounded retry, DEGRADED completion, FAILED past cap


def test_prefill_fault_retries_then_degraded():
    _, clean = _drive(_engine())
    inj = FaultInjector(seed=0, schedule=[FaultEvent("prefill_error", 0)],
                        clock=FakeClock())
    sched, results = _drive(_engine(), faults=inj,
                            retry=RetryPolicy(max_attempts=3))
    got = _by_rid(results)
    assert got[0].status is RequestStatus.DEGRADED
    for rid, r in _by_rid(clean).items():
        np.testing.assert_array_equal(got[rid].tokens, r.tokens)
    assert sched.audit(results)["ok"]


def test_decode_fault_storm_fails_active_slots():
    inj = FaultInjector(seed=0, rates={"decode_error": 1.0},
                        clock=FakeClock())
    sched, results = _drive(_engine(), faults=inj,
                            retry=RetryPolicy(max_attempts=2))
    assert len(results) == 5
    # the first token comes from prefill logits, so a request can only be
    # OK here if it never needed a decode step (budget 1, or EOS first);
    # everything that entered decode must have been FAILED at the cap
    for r in results:
        assert r.status in (RequestStatus.OK, RequestStatus.FAILED)
        if r.status is RequestStatus.OK:
            assert r.tokens.size <= 1
        else:
            assert "decode failed" in r.error
    assert _by_rid(results)[3].status is RequestStatus.OK   # budget-1 request
    assert any(r.status is RequestStatus.FAILED for r in results)
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]     # slot resets released every page


# ---------------------------------------------------------------------------
# Deadlines + admission valve


def test_deadline_timeout_while_queued():
    clk = FakeClock()
    eng = _engine()
    eng.attach_faults(None)
    sched = Scheduler(eng, clock=clk)
    for r in _requests(deadline_s=5.0):
        sched.submit(r)
    clk.advance(10.0)                   # every deadline elapses pre-run
    results = sched.run_continuous()
    assert len(results) == 5
    assert all(r.status is RequestStatus.TIMEOUT for r in results)
    assert all(r.tokens.size == 0 for r in results)
    assert sched.audit(results)["ok"]


def test_clock_skew_times_out_inflight_requests():
    clk = FakeClock()
    inj = FaultInjector(seed=0, rates={"clock_skew": 1.0}, skew_s=50.0,
                        clock=clk)
    sched, results = _drive(_engine(), faults=inj,
                            reqs=_requests(deadline_s=5.0))
    assert len(results) == 5
    assert all(r.status in (RequestStatus.TIMEOUT, RequestStatus.OK,
                            RequestStatus.DEGRADED) for r in results)
    assert any(r.status is RequestStatus.TIMEOUT for r in results)
    assert sched.audit(results)["ok"]


def test_admission_valve_sheds_at_submit():
    sched, results = _drive(_engine(), valve=AdmissionValve(max_queue=2))
    assert len(results) == 5            # 2 served + 3 shed, all accounted
    shed = [r for r in results if r.attempts == 0]
    assert len(shed) == 3
    assert all(r.status is RequestStatus.REJECTED for r in shed)
    served = [r for r in results if r.attempts > 0]
    assert all(r.status is RequestStatus.OK for r in served)
    assert sched.audit(results)["ok"]


# ---------------------------------------------------------------------------
# Satellite: trie refcount pinning under eviction + TTL expiry mid-flight


def test_trie_pin_survives_eviction_and_ttl_then_drains():
    clk = FakeClock()
    pc = PrefixCache(chunk=2, budget_bytes=1 << 20, ttl=10.0, clock=clk)
    a = np.array([1, 2, 3, 4], np.int32)
    b = np.array([5, 6, 7, 8], np.int32)
    pc.insert(a, [np.ones((2, 4), np.float32)] * 2)
    pc.insert(b, [np.ones((2, 4), np.float32)] * 2)
    match = pc.match(a)                 # pin path A (warm prefill in flight)
    assert match.n_chunks == 2
    clk.advance(100.0)                  # everything is TTL-stale now
    pc.evict_bytes(1 << 30)             # forced eviction storm mid-flight
    # the pinned path survived: its payloads are still retrievable
    for nd in match.nodes:
        assert pc.store.get(nd.handle) is not None
    # the unpinned path B is prunable: a walk onto it must not serve it
    assert pc.match(b).n_chunks == 0
    assert pc.audit()["ok"], pc.audit()["issues"]
    pc.release(match)
    # after release the stale pinned path prunes on the next walk and its
    # handles drain out of pending_free into the store's free path
    assert pc.match(a).n_chunks == 0
    assert pc.trie.n_nodes == 0
    assert len(pc.trie.pending_free) == 0
    assert len(pc.store) == 0
    assert pc.audit()["ok"]


def test_chaos_with_prefix_cache_trie_eviction_midflight():
    """Paged + prefix-cache engine under forced mid-flight trie eviction +
    TTL skew: no crash, every rid resolves, pool/trie audits clean.  (Token
    bit-identity across warm/cold is bucket-dependent, so this test pins
    lifecycle invariants, not payload equality — see docs/serving.md §2.)"""
    clk = FakeClock()
    eng = _engine("paged_prefix", prefix_cache=True,
                  prefix_cache_bytes=1 << 16, prefix_cache_ttl=30.0,
                  prefill_mode="streaming", clock=clk)
    inj = FaultInjector(seed=2, rates={"trie_evict": 0.5, "clock_skew": 0.3},
                        skew_s=40.0, clock=clk)
    reqs = _requests(seed=1) + [
        Request(rid=10 + i, tokens=np.asarray(r.tokens),
                max_new_tokens=r.max_new_tokens)
        for i, r in enumerate(_requests(seed=1)[:3])]   # warm repeats
    sched, results = _drive(eng, faults=inj, reqs=reqs)
    assert len(results) == len(reqs)
    assert all(r.status in tuple(RequestStatus) for r in results)
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]


# ---------------------------------------------------------------------------
# Satellite: hypothesis chaos property (+ seeded deterministic twin)


def _check_schedule(seed, p_pool, p_nan, p_dec):
    eng = _engine()
    _, clean = _drive(eng, reqs=_requests(seed=3))
    clean_by = _by_rid(clean)
    inj = FaultInjector(seed=seed, clock=FakeClock(),
                        rates={"pool_exhausted": p_pool, "nan_chunk": p_nan,
                               "decode_error": p_dec})
    sched, results = _drive(eng, faults=inj, reqs=_requests(seed=3),
                            retry=RetryPolicy(max_attempts=2, backoff_s=0.01))
    # every rid exactly one typed result
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]
    # zero page leaks under any schedule
    pool_rep = eng.pool.audit()
    assert pool_rep["ok"], pool_rep["issues"]
    # fault-untouched (completed) requests are bit-identical to the twin
    for r in results:
        assert isinstance(r.status, RequestStatus)
        if r.status in (RequestStatus.OK, RequestStatus.DEGRADED):
            np.testing.assert_array_equal(r.tokens, clean_by[r.rid].tokens)


@pytest.mark.parametrize("seed,p_pool,p_nan,p_dec", [
    (0, 0.0, 0.0, 0.0),
    (1, 0.4, 0.0, 0.0),
    (2, 0.0, 0.4, 0.0),
    (3, 0.0, 0.0, 0.3),
    (4, 0.3, 0.3, 0.2),
])
def test_chaos_schedule_invariants_seeded(seed, p_pool, p_nan, p_dec):
    _check_schedule(seed, p_pool, p_nan, p_dec)


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(seed=st.integers(0, 2**16),
           p_pool=st.sampled_from([0.0, 0.25, 0.6]),
           p_nan=st.sampled_from([0.0, 0.25]),
           p_dec=st.sampled_from([0.0, 0.25]))
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_chaos_schedule_invariants_property(seed, p_pool, p_nan, p_dec):
        _check_schedule(seed, p_pool, p_nan, p_dec)
except ImportError:                      # seeded twin above keeps coverage
    pass


# ---------------------------------------------------------------------------
# Auditor sharp edges: it must actually catch manufactured corruption


def test_pool_audit_catches_manufactured_leak():
    pool = PagePool(n_pages=6, batch=2, n_chunks=4, page_bytes=128)
    pool.admit(0, 2)
    clean = pool.audit(retained=[])     # slot row accounts for every ref
    assert clean["ok"], clean["issues"]
    page = int(pool.block_tables[0, 0])
    pool.retain(page)                   # dangling reference with no holder
    rep = pool.audit(retained=[])
    assert not rep["ok"]
    assert any(f"page {page}" in m for m in rep["issues"])
    # declaring it as a trie-held handle reconciles the exact count
    held = pool.audit(retained=[page])
    assert held["ok"], held["issues"]
    pool.release(page)
    pool.release_slot(0)
    end = pool.audit(retained=[])
    assert end["ok"] and end["used_pages"] == 0


def test_fault_injector_is_deterministic():
    def mk():
        return FaultInjector(seed=7, rates={"decode_error": 0.5},
                             schedule=[FaultEvent("nan_chunk", 2)])
    a, b = mk(), mk()
    for _ in range(32):
        assert a.fire("decode_error") == b.fire("decode_error")
        assert a.fire("nan_chunk") == b.fire("nan_chunk")
    assert a.log == b.log
    assert a.fired["nan_chunk"] >= 1     # the scheduled event fired


# ---------------------------------------------------------------------------
# Telemetry under chaos (ISSUE 10 satellite): fault schedules may retry,
# reject, quarantine, or fail requests — the tracer must still finish
# exactly ONE trace per submitted rid, with statuses matching the results


@pytest.mark.obs
@pytest.mark.parametrize("rates", [
    {"pool_exhausted": 0.3, "nan_chunk": 0.2},
    {"prefill_error": 0.3, "decode_error": 0.15},
    {"pool_exhausted": 0.2, "nan_chunk": 0.1, "decode_error": 0.1,
     "clock_skew": 0.05},
])
def test_obs_one_trace_per_rid_under_chaos(rates):
    from repro.serving import ObsConfig
    eng = _engine("paged_obs", obs=ObsConfig())
    clk = FakeClock()
    inj = FaultInjector(seed=11, rates=rates, clock=clk)
    eng.obs.tracer.reset()              # engines are shared across params

    def by_status():
        return {s["labels"]["status"]: s["value"] for s in
                eng.obs.registry.get("serving_results_total").series()}

    before = by_status()                # counters are engine-lifetime
    sched, results = _drive(eng, faults=inj, clock=clk,
                            retry=RetryPolicy(max_attempts=2, backoff_s=0.1))
    rids = [r.rid for r in _requests()]
    cov = eng.obs.tracer.coverage(rids)
    assert cov["complete"], cov
    assert cov["statuses"] == {r.rid: str(r.status) for r in results}
    # registry result totals stay in lockstep with the typed results even
    # when terminal paths differ (shed / rejected / failed / ok)
    after = by_status()
    delta = {k: after[k] - before.get(k, 0.0) for k in after
             if after[k] != before.get(k, 0.0)}
    assert delta == {k: float(v) for k, v in sched.last_stats["statuses"].items()}
    assert sched.audit(results)["ok"]
