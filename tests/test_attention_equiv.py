"""Cross-path attention equivalence: the model's chunked XLA attention, the
flash Pallas kernel, and the naive oracle must agree on every mask family."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels import ref
from repro.models import attention as attn_lib


@pytest.mark.parametrize("kind,window,prefix", [
    ("global", 0, 0), ("local", 24, 0), ("global", 0, 8),
])
def test_model_attention_matches_flash_kernel(kind, window, prefix, rng):
    import dataclasses
    cfg = smoke_config("minicpm-2b")
    cfg = dataclasses.replace(cfg, local_window=window or cfg.local_window)
    B, S, Dh = 2, 64, cfg.head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    q = jax.random.normal(rng, (B, S, Hq, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Hkv, Dh), jnp.float32)
    positions = jnp.arange(S)

    out_model = attn_lib._sdpa_chunked(cfg, q, k, v, positions, kind, prefix, q_chunk=16)

    # flash kernel operates per (B·H) with GQA pre-expanded
    G = Hq // Hkv
    k_e = jnp.repeat(k, G, axis=2)
    v_e = jnp.repeat(v, G, axis=2)
    fl = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * Hq, S, Dh)
    out_kernel = flash_prefill(fl(q), fl(k_e), fl(v_e), bq=16, bk=16,
                               window=window if kind == "local" else 0,
                               prefix_len=prefix, interpret=True)
    out_kernel = jnp.moveaxis(out_kernel.reshape(B, Hq, S, Dh), 1, 2)

    out_ref = ref.flash_prefill_ref(fl(q), fl(k_e), fl(v_e), positions,
                                    causal=True,
                                    window=window if kind == "local" else 0,
                                    prefix_len=prefix)
    out_ref = jnp.moveaxis(out_ref.reshape(B, Hq, S, Dh), 1, 2)

    # model path materializes bf16 scores/probs: tolerance at bf16 scale
    assert jnp.allclose(out_model.astype(jnp.float32), out_ref, atol=3e-2)
    assert jnp.allclose(out_kernel, out_ref, atol=1e-4)
