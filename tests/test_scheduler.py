"""Slot-level continuous batching: splice isolation, EOS truncation,
throughput accounting, cache byte accounting, and pspec legality.

The archetype test is splice isolation: a request spliced into a live batch
mid-decode must produce bit-identical greedy tokens to running it alone —
for every cache kind (gear / fp16 / window).  This pins the per-slot cache
layout, per-slot RoPE, and batch-invariant compression all at once.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.cache import CacheConfig
from repro.core.outlier import outlier_count
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Request, Scheduler, _pad

EOS = 3
PROMPT_PAD = 8
GEAR_POL = dataclasses.replace(named_policy("gear_kcvt4"),
                               buffer_size=8, rank=2, rank_decode=2)

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                   vocab_size=64)
# local+global pattern -> one sliding-window (ring) cache and one full cache
TINY_WIN = dataclasses.replace(TINY, attn_pattern="local_global",
                               pattern_locals=1, local_window=8)

KINDS = {
    "gear": (TINY, GEAR_POL),
    "fp16": (TINY, FP16),
    "window": (TINY_WIN, FP16),
}

_ENGINES: dict = {}


def _engines(kind):
    """(batched engine, solo engine) pair per cache kind, built once."""
    if kind not in _ENGINES:
        cfg, pol = KINDS[kind]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ecfg = EngineConfig(batch=3, capacity=48, policy=pol, eos_id=EOS)
        _ENGINES[kind] = (Engine(model, params, ecfg),
                         Engine(model, params, dataclasses.replace(ecfg, batch=1)))
    return _ENGINES[kind]


def _requests(n=6, seed=0, length=None):
    rng = np.random.RandomState(seed)
    budgets = [6, 3, 9, 1, 5, 7, 2, 8][:n]
    return [Request(rid=i,
                    tokens=rng.randint(4, 64,
                                       size=length or rng.randint(2, PROMPT_PAD + 1)),
                    max_new_tokens=b)
            for i, b in enumerate(budgets)]


def _solo_reference(solo: Engine, req: Request) -> np.ndarray:
    """The request run alone through a batch-1 scheduler: the same raw-length
    prefill path (including any engine-side length bucketing) as the batched
    run, with no other slot live."""
    sched = Scheduler(solo)
    sched.submit(Request(rid=0, tokens=req.tokens,
                         max_new_tokens=req.max_new_tokens))
    (res,) = sched.run_continuous()
    return res.tokens


# ---------------------------------------------------------------------------
# Splice isolation (the archetype)


@pytest.mark.parametrize("kind", ["gear", "fp16", "window"])
def test_splice_isolation_bit_identical(kind):
    """Continuous-batched greedy output == solo output, token for token."""
    eng, solo = _engines(kind)
    sched = Scheduler(eng)
    reqs = _requests()
    for r in reqs:
        sched.submit(r)
    out = {r.rid: r.tokens for r in sched.run_continuous()}
    assert sorted(out) == [r.rid for r in reqs]
    for r in reqs:
        ref = _solo_reference(solo, r)
        np.testing.assert_array_equal(
            out[r.rid], ref,
            err_msg=f"{kind}: rid {r.rid} diverged from its solo run")


@pytest.mark.parametrize("kind", ["gear", "fp16", "window"])
def test_wave_and_continuous_agree(kind):
    """Both scheduling modes return the same per-request greedy tokens.

    Equal-length prompts: wave mode pads each wave to its longest raw
    prompt, so only equal lengths give both modes the same prefill
    program (the mixed-length caveat in the scheduler module docstring).
    """
    eng, _ = _engines(kind)
    reqs = _requests(length=6)
    outs = []
    for mode in ("run", "run_continuous"):
        sched = Scheduler(eng)
        for r in reqs:
            sched.submit(r)
        outs.append({r.rid: r.tokens for r in getattr(sched, mode)()})
    for rid in outs[0]:
        np.testing.assert_array_equal(outs[0][rid], outs[1][rid])


def test_continuous_per_request_latency_and_budgets():
    eng, _ = _engines("gear")
    sched = Scheduler(eng)
    reqs = _requests()
    for r in reqs:
        sched.submit(r)
    results = sched.run_continuous()
    budgets = {r.rid: r.max_new_tokens for r in reqs}
    for res in results:
        assert 1 <= len(res.tokens) <= budgets[res.rid]
        assert res.prefill_s >= 0 and res.decode_s >= 0
        if len(res.tokens) < budgets[res.rid]:       # ended early => own EOS
            assert res.tokens[-1] == EOS
        assert EOS not in res.tokens[:-1]            # nothing past first EOS
    assert sched.last_stats["decode_steps"] > 0


@pytest.mark.kernel
def test_splice_isolation_through_interpret_kernel():
    """Continuous batching with the REAL Pallas kernel (interpret mode on
    CPU): mixed-length batches dispatch the ragged fused path end to end and
    every request still matches its solo run token for token."""
    cfg, pol = KINDS["gear"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch=3, capacity=48, policy=pol, eos_id=EOS,
                        fused="interpret")
    eng = Engine(model, params, ecfg)
    solo = Engine(model, params, dataclasses.replace(ecfg, batch=1))
    sched = Scheduler(eng)
    reqs = _requests(4)
    for r in reqs:
        sched.submit(r)
    out = {r.rid: r.tokens for r in sched.run_continuous()}
    assert sched.last_stats["attend_path"] == "fused-interpret"
    for r in reqs:
        np.testing.assert_array_equal(
            out[r.rid], _solo_reference(solo, r),
            err_msg=f"interpret-kernel rid {r.rid} diverged from its solo run")


def test_decode_dispatches_fused_gear_attend(monkeypatch):
    """The engine's decode program routes GEAR layers through gear_attend
    (the fused path) — including for mixed-length position vectors — and
    fp16 engines stay on the jnp attend path."""
    from repro.kernels import ops as kernel_ops

    calls = []
    real = kernel_ops.gear_attend

    def spy(*a, **kw):
        calls.append(kw.get("force_kernel", False))
        return real(*a, **kw)

    monkeypatch.setattr(kernel_ops, "gear_attend", spy)
    cfg, pol = KINDS["gear"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(batch=2, capacity=48, policy=pol))
    assert eng.attend_path == "fused"
    caches = eng.init_caches()
    tb = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    eng.decode(tb, caches, jnp.asarray([5, 17], jnp.int32))   # mixed lengths
    assert calls, "decode trace never reached gear_attend"
    assert not any(calls)                                     # real kernel path, not forced

    calls.clear()
    fcfg, fpol = KINDS["fp16"]
    feng = Engine(build_model(fcfg), build_model(fcfg).init(jax.random.PRNGKey(0)),
                  EngineConfig(batch=2, capacity=48, policy=fpol))
    assert feng.attend_path == "xla"
    feng.decode(tb, feng.init_caches(), jnp.asarray([0, 0], jnp.int32))
    assert not calls


# ---------------------------------------------------------------------------
# Wave-mode satellite fixes


def test_wave_results_truncated_at_own_eos():
    eng, _ = _engines("gear")
    sched = Scheduler(eng)
    for r in _requests():
        sched.submit(r)
    for res in sched.run():
        assert EOS not in res.tokens[:-1], (
            f"rid {res.rid} kept tokens after its own EOS")


def test_decode_tok_per_s_excludes_copy_slots_and_post_eos():
    eng, _ = _engines("gear")
    prompt = np.tile(_pad(_requests()[0].tokens, PROMPT_PAD), (3, 1))
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
    budget = 6
    eng.generate(batch, budget)   # warmup: keep jit compile out of decode_s
    toks, stats_all = eng.generate(batch, budget)
    active = np.array([True, False, False])          # 2 padded copy slots
    _, stats_one = eng.generate(batch, budget, active=active)
    # identical prompts => identical decode work, but only 1/3 of it useful
    assert stats_one["decode_tok_per_s"] < stats_all["decode_tok_per_s"]
    tnp = np.asarray(toks)
    hits = np.nonzero(tnp[0] == EOS)[0]
    n_use = (hits[0] + 1 if hits.size else tnp.shape[1]) - 1
    assert stats_one["decode_tok_per_s"] == pytest.approx(
        n_use / stats_one["decode_s"], rel=0.5)


# ---------------------------------------------------------------------------
# Cache byte accounting (pins the compression-ratio claim)


def _expected_gear_layer_bytes(ccfg: CacheConfig) -> int:
    """Closed-form byte count of one GEAR (kcvt) layer cache."""
    B, H, Dh, S = ccfg.batch, ccfg.kv_heads, ccfg.head_dim, ccfg.capacity
    pol = ccfg.policy
    per = 32 // pol.bits
    C, nb, r = ccfg.n_chunks, ccfg.chunk, pol.rank
    total = 2 * B * H * S * (Dh // per) * 4              # packed K+V codes
    total += 2 * B * H * C * Dh * 2                      # K scale+zero (per-channel)
    total += 2 * B * H * S * 1 * 2                       # V scale+zero (per-token)
    total += 2 * (B * H * S * r + B * H * C * Dh * r) * 2  # low-rank A + B, K+V
    ks = outlier_count(nb, pol.sparsity)                 # K outliers per chunk col
    kv = outlier_count(Dh, pol.sparsity)                 # V outliers per token row
    total += (B * H * C * Dh * 2 * ks + B * H * S * 2 * kv) * (2 + 4)  # val+idx
    total += 2 * B * H * nb * Dh * 2                     # fp16 streaming buffer
    total += B * 4                                       # per-slot lengths
    return total


def test_engine_cache_nbytes_matches_closed_form():
    eng, _ = _engines("gear")
    R = TINY.pattern_repeats
    ccfg = CacheConfig(batch=3, kv_heads=TINY.num_kv_heads, head_dim=TINY.head_dim,
                       capacity=48, policy=GEAR_POL)
    expected = R * _expected_gear_layer_bytes(ccfg)
    got = Engine.cache_nbytes(eng.init_caches())
    assert got == expected, (got, expected)

    fp16_eng, _ = _engines("fp16")
    fp16_cap = fp16_eng._cap()        # engine rounds 48 up to FP16's 64-buffer
    fp16_expected = R * (2 * 3 * TINY.num_kv_heads * fp16_cap * TINY.head_dim * 2
                         + 3 * 4)
    fp16_got = Engine.cache_nbytes(fp16_eng.init_caches())
    assert fp16_got == fp16_expected, (fp16_got, fp16_expected)


def test_gear_cache_strictly_below_fp16_at_paper_geometry():
    """The compression-ratio claim, pinned on real allocations: at the
    paper's serving geometry a GEAR layer cache is strictly smaller than the
    FP16 cache of the same capacity (the toy test geometry above is too
    small for chunk overheads to amortize — that regime is fp16's)."""
    from repro.core.cache import init_layer_cache

    pol = named_policy("gear_kcvt4")
    gear_cfg = CacheConfig(batch=2, kv_heads=8, head_dim=128, capacity=1024,
                           policy=pol)
    fp16_cfg = dataclasses.replace(gear_cfg, policy=FP16, kind="fp16")
    gear_bytes = Engine.cache_nbytes(init_layer_cache(gear_cfg))
    fp16_bytes = Engine.cache_nbytes(init_layer_cache(fp16_cfg))
    assert gear_bytes == _expected_gear_layer_bytes(gear_cfg)
    assert gear_bytes < fp16_bytes
    # 4-bit backbone + factors should land well under half of fp16
    assert gear_bytes / fp16_bytes < 0.55, gear_bytes / fp16_bytes


# ---------------------------------------------------------------------------
# Sharding: the slot-splice donation path keeps legal cache pspecs


def test_cache_pspecs_legal_and_splice_runs_under_mesh():
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_test_mesh

    # Use a real data-parallel axis when the topology allows (CI's full lane
    # fakes 8 host devices), so the traced-offset batch-row splice actually
    # crosses shard boundaries; single-device runs still smoke the specs.
    nd = jax.device_count()
    if nd >= 4:
        mesh = make_test_mesh(data=2, model=2)
    elif nd >= 2:
        mesh = make_test_mesh(data=2, model=1)
    else:
        mesh = make_test_mesh(data=1, model=1)
    cfg, pol = KINDS["gear"]
    model = build_model(cfg)
    cache_abs = jax.eval_shape(lambda: model.init_caches(pol, 2, 48))
    specs = shd.cache_pspecs(cfg, cache_abs, mesh, batch=2)
    # every spec must be realizable on the mesh (fit_spec already legalized)
    shd.shardings_for(mesh, specs)
    # per-slot scalars (length [R, B], window pos [R, B, W]) flow through too
    for leaf, spec in zip(jax.tree.leaves(cache_abs), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))):
        assert len(spec) <= len(leaf.shape)

    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(batch=2, capacity=48, policy=pol, eos_id=EOS),
                 mesh=mesh)
    caches = eng.init_caches()
    prompt = _pad(_requests()[0].tokens, PROMPT_PAD)[None]
    _, caches = eng.prefill_slot({"tokens": jnp.asarray(prompt, jnp.int32)},
                                 caches, 1)
    tb = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    logits, _ = eng.decode(tb, caches, jnp.asarray([0, PROMPT_PAD], jnp.int32))
    assert logits.shape[0] == 2


# ---------------------------------------------------------------------------
# Streaming chunked prefill through the serving stack


def test_splice_isolation_streaming_prefill():
    """Continuous batching with ``prefill_mode="streaming"``: every spliced
    request's greedy tokens stay bit-identical to a solo run on a streaming
    engine — the compress-as-you-go pipeline preserves the batch-invariant
    compression and per-slot isolation the splice protocol relies on."""
    cfg, pol = KINDS["gear"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch=3, capacity=48, policy=pol, eos_id=EOS,
                        prefill_mode="streaming")
    eng = Engine(model, params, ecfg)
    solo = Engine(model, params, dataclasses.replace(ecfg, batch=1))
    sched = Scheduler(eng)
    reqs = _requests()
    for r in reqs:
        sched.submit(r)
    out = {r.rid: r.tokens for r in sched.run_continuous()}
    for r in reqs:
        np.testing.assert_array_equal(
            out[r.rid], _solo_reference(solo, r),
            err_msg=f"streaming rid {r.rid} diverged from its solo run")


def test_streaming_and_monolithic_engine_caches_agree():
    """Engine-level prefill-mode parity.  Given identical K/V the two modes
    are bit-exact (pinned at cache level in test_cache); through the model
    the per-chunk vs full-sequence projection GEMMs may differ by 1 ulp of
    bf16, so here the caches must agree up to that jitter: identical
    geometry, (near-)identical leaves, a ≪1% budget of flipped codes."""
    cfg, pol = KINDS["gear"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = _pad(_requests()[0].tokens, PROMPT_PAD)[None]
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
    caches = {}
    for mode in ("monolithic", "streaming"):
        ecfg = EngineConfig(batch=1, capacity=48, policy=pol, eos_id=-1,
                            prefill_mode=mode)
        eng = Engine(model, params, ecfg)
        _, caches[mode] = eng.prefill(batch)
    assert (Engine.cache_nbytes(caches["monolithic"])
            == Engine.cache_nbytes(caches["streaming"]))
    # Leaf-wise bit comparison would be unstable (outlier *selection* is
    # discontinuous in the 1-ulp projection jitter), so compare what decode
    # actually consumes: the dense reconstruction of every layer cache.
    from repro.core.cache import dense_kv
    from repro.models.transformer import cache_cfg_for
    ccfg = cache_cfg_for(cfg, "global", pol, 1, 48)
    for r in range(cfg.pattern_repeats):
        lm = jax.tree.map(lambda t: t[r], caches["monolithic"][0])
        ls = jax.tree.map(lambda t: t[r], caches["streaming"][0])
        np.testing.assert_array_equal(np.asarray(lm.length), np.asarray(ls.length))
        for m_side, s_side in zip(dense_kv(ccfg, lm), dense_kv(ccfg, ls)):
            diff = np.abs(np.asarray(m_side) - np.asarray(s_side))
            assert float(diff.mean()) < 0.01         # jitter, not divergence
            assert float((diff > 0.05).mean()) < 0.01


def test_engine_config_rejects_unknown_prefill_mode():
    cfg, pol = KINDS["gear"]
    with pytest.raises(ValueError, match="prefill_mode"):
        EngineConfig(batch=1, capacity=48, policy=pol, prefill_mode="nope")


# ---------------------------------------------------------------------------
# Hypothesis property: splice-after-streaming-prefill is bit-exact

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:                                    # fast lane w/o extras
    HAS_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class hyp_st:                                      # placeholder strategies
        integers = sampled_from = staticmethod(lambda *a, **k: None)


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@given(seed=hyp_st.integers(0, 2**16),
       n_new=hyp_st.sampled_from([5, 8, 19]),
       slot=hyp_st.integers(0, 2))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow] if HAS_HYPOTHESIS else [])
def test_property_splice_after_streaming_prefill_bit_exact(seed, n_new, slot):
    """A batch-1 STREAMING prefill spliced into a live streaming-prefilled
    batch lands bit-exactly (spliced row == solo row, other rows untouched)
    for any prompt length phase (buffer-only / chunk-boundary / mixed) and
    any slot — the cache-level half of splice isolation for the new prefill
    pipeline."""
    from repro.core import (CacheConfig, init_layer_cache, named_policy,
                            splice_slot, streaming_prefill_layer_cache)
    B, H, DH = 3, 2, 32
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=8,
                              rank=2)
    ccfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=32, policy=pol)
    key = jax.random.PRNGKey(seed)
    qb = jax.random.normal(key, (B, 2 * H, 24, DH))
    kb = jax.random.normal(jax.random.fold_in(key, 1), (B, H, 24, DH))
    vb = jax.random.normal(jax.random.fold_in(key, 2), (B, H, 24, DH))
    live, _ = streaming_prefill_layer_cache(ccfg, init_layer_cache(ccfg),
                                            qb, kb, vb, DH**-0.5)

    cfg1 = dataclasses.replace(ccfg, batch=1)
    q1 = jax.random.normal(jax.random.fold_in(key, 3), (1, 2 * H, n_new, DH))
    k1 = jax.random.normal(jax.random.fold_in(key, 4), (1, H, n_new, DH))
    v1 = jax.random.normal(jax.random.fold_in(key, 5), (1, H, n_new, DH))
    solo, _ = streaming_prefill_layer_cache(cfg1, init_layer_cache(cfg1),
                                            q1, k1, v1, DH**-0.5)

    spliced = splice_slot(live, solo, slot)
    for name in ("k_packed", "v_packed", "k_scale", "v_scale", "k_a", "k_b",
                 "v_a", "v_b", "k_sp_val", "k_sp_idx", "v_sp_val", "v_sp_idx",
                 "buf_k", "buf_v", "length"):
        got, want, before = (getattr(spliced, name), getattr(solo, name),
                             getattr(live, name))
        if got is None:
            continue
        got, want, before = np.asarray(got), np.asarray(want), np.asarray(before)
        np.testing.assert_array_equal(got[slot], want[0], err_msg=name)
        others = [s for s in range(B) if s != slot]
        np.testing.assert_array_equal(got[others], before[others],
                                      err_msg=f"{name} (untouched rows)")


_BUCKET_ENGINE: list = []


def _bucket_engine() -> Engine:
    if not _BUCKET_ENGINE:
        cfg, pol = KINDS["gear"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUCKET_ENGINE.append(Engine(model, params, EngineConfig(
            batch=1, capacity=48, policy=pol, eos_id=-1,
            prefill_mode="streaming")))
    return _BUCKET_ENGINE[0]


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@given(n=hyp_st.integers(2, 40), seed=hyp_st.integers(0, 2**16))
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow] if HAS_HYPOTHESIS else [])
def test_property_length_bucketing_preserves_logits(n, seed):
    """Engine-side length bucketing (pad the prompt up to the next n_b
    multiple, run the padded-tail streaming pipeline) never changes WHAT
    the engine serves: cache lengths stay the raw length and the last-
    position logits match an exact-length streaming prefill.  The bucketed
    and exact tails attend at different static widths, so XLA may reorder
    the tail reductions — logits agree to round-off, not necessarily
    bit-for-bit.  (Warm vs cold BUCKETED runs, which share tail widths,
    ARE bitwise — see tests/test_prefixcache.py.)"""
    eng = _bucket_engine()
    assert eng._can_bucket
    rng = np.random.RandomState(seed)
    toks = rng.randint(4, 64, size=n)
    batch = {"tokens": jnp.asarray(toks[None], jnp.int32)}
    exact_logits, _ = eng._prefill(eng.params, batch)
    bucket_logits, bucket_caches = eng._cold_prefill(batch)
    for c in bucket_caches:
        np.testing.assert_array_equal(np.asarray(c.length), n)
    np.testing.assert_allclose(
        np.asarray(bucket_logits, np.float32),
        np.asarray(exact_logits, np.float32), atol=0.05, rtol=0.05)


def test_streaming_engine_falls_back_for_unsupported_layout():
    """An engine whose policy lacks the streaming layout (fine-grained K
    groups) still serves under prefill_mode="streaming": every layer takes
    the monolithic fallback, so prefill+decode run and match a monolithic
    engine bit-for-bit."""
    cfg, _ = KINDS["gear"]
    pol = dataclasses.replace(named_policy("gear_kivi2"), buffer_size=8,
                              group=4, rank=2, rank_decode=2)  # group != chunk
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = _pad(_requests()[0].tokens, PROMPT_PAD)[None]
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
    outs = {}
    for mode in ("monolithic", "streaming"):
        eng = Engine(model, params, EngineConfig(batch=1, capacity=48,
                                                 policy=pol, eos_id=-1,
                                                 prefill_mode=mode))
        toks, _ = eng.generate(batch, 6)
        outs[mode] = np.asarray(toks)
    np.testing.assert_array_equal(outs["monolithic"], outs["streaming"])
