"""The CI bench regression gate (benchmarks/check_regression.py): the gate
must pass an intentionally-clean run and fail an intentionally-broken one —
throughput regressions past tolerance, any cache-byte growth, and silently
missing metrics all have to trip it."""

import json

import pytest

from benchmarks.check_regression import DEFAULT_BASELINE, check, governed, main


BASE = {
    "throughput_fused/decode_tok_per_s_fused": 400.0,
    "throughput_fused/fused_over_xla": 1.4,
    "cache_nbytes/bench_engine_gear": 1000,
}


def _rows(**over):
    rows = {"throughput_fused/decode_tok_per_s_fused": 400.0,
            "throughput_fused/fused_over_xla": 1.4,
            "cache_nbytes/bench_engine_gear": 1000}
    rows.update(over)
    return rows


def test_clean_run_passes():
    assert check(BASE, _rows(), tol=0.15) == []
    # within-tolerance jitter and improvements also pass
    assert check(BASE, _rows(**{
        "throughput_fused/decode_tok_per_s_fused": 360.0,     # -10%
        "cache_nbytes/bench_engine_gear": 900,                # bytes shrank
    }), tol=0.15) == []


def test_throughput_regression_fails():
    fails = check(BASE, _rows(**{
        "throughput_fused/decode_tok_per_s_fused": 300.0}), tol=0.15)  # -25%
    assert len(fails) == 1 and "decode_tok_per_s" in fails[0]


def test_ratio_regression_fails():
    """fused-over-XLA collapsing toward 1.0 = fused path silently fell back."""
    fails = check(BASE, _rows(**{"throughput_fused/fused_over_xla": 1.0}),
                  tol=0.15)
    assert len(fails) == 1 and "fused_over_xla" in fails[0]


def test_any_cache_byte_growth_fails():
    fails = check(BASE, _rows(**{"cache_nbytes/bench_engine_gear": 1001}),
                  tol=0.15)
    assert len(fails) == 1 and "nbytes" in fails[0]


def test_missing_metric_fails():
    rows = _rows()
    del rows["cache_nbytes/bench_engine_gear"]
    fails = check(BASE, rows, tol=0.15)
    assert len(fails) == 1 and "missing" in fails[0]


def test_governed_name_families():
    assert governed("throughput_fused/decode_tok_per_s_fused")
    assert governed("cache_nbytes/bench_engine_gear")
    assert governed("throughput_sched/continuous_over_wave")
    assert not governed("table9_kvsize/gear_kcvt4")


def test_end_to_end_exit_codes(tmp_path):
    """main() over real files: clean exits 0, broken exits 1, derate scales
    only the absolute tok/s floors at --write-baseline time."""
    out = tmp_path / "bench-out"
    out.mkdir()
    rows = [{"name": n, "us_per_call": 0.0, "derived": "", "value": v}
            for n, v in _rows().items()]
    (out / "t.json").write_text(json.dumps(rows))
    baseline = tmp_path / "baseline.json"
    assert main([str(out), "--baseline", str(baseline),
                 "--write-baseline", "--derate", "0.5"]) == 0
    written = json.loads(baseline.read_text())
    assert written["throughput_fused/decode_tok_per_s_fused"] == 200.0  # derated
    assert written["throughput_fused/fused_over_xla"] == 1.4            # exact
    assert written["cache_nbytes/bench_engine_gear"] == 1000            # exact

    assert main([str(out), "--baseline", str(baseline)]) == 0
    broken = [dict(r, value=r["value"] + 1 if "nbytes" in r["name"] else r["value"])
              for r in rows]
    (out / "t.json").write_text(json.dumps(broken))
    assert main([str(out), "--baseline", str(baseline)]) == 1


def test_committed_baseline_is_governed_and_loadable():
    """The checked-in baseline only names metrics the gate governs."""
    with open(DEFAULT_BASELINE) as f:
        base = json.load(f)
    assert base, "committed baseline is empty"
    for name, val in base.items():
        assert governed(name), name
        assert isinstance(val, (int, float))


def test_empty_bench_dir_is_loud(tmp_path):
    with pytest.raises(SystemExit):
        main([str(tmp_path / "nothing"), "--baseline", "x.json"])


def test_peak_bytes_rule_and_governance():
    """prefill_peak_bytes rows are governed with 5% compiler headroom —
    growth beyond it fails, shrink and small jitter pass."""
    assert governed("prefill_peak_bytes/streaming")
    base = {"prefill_peak_bytes/streaming": 1000.0}
    assert check(base, {"prefill_peak_bytes/streaming": 1040.0}, tol=0.15) == []
    assert check(base, {"prefill_peak_bytes/streaming": 500.0}, tol=0.15) == []
    fails = check(base, {"prefill_peak_bytes/streaming": 1100.0}, tol=0.15)
    assert len(fails) == 1 and "peak_bytes" in fails[0]
    # custom headroom
    assert check(base, {"prefill_peak_bytes/streaming": 1100.0}, tol=0.15,
                 mem_tol=0.2) == []


def test_derate_never_touches_ratio_rows(tmp_path):
    """--derate must leave *_over_* ratio floors exact even when the row
    name also contains tok_per_s (prefill_tok_per_s/streaming_over_monolithic)
    — otherwise the documented refresh command would silently weaken the
    machine-independent prefill guard."""
    out = tmp_path / "bench-out"
    out.mkdir()
    rows = [{"name": n, "us_per_call": 0.0, "derived": "", "value": v}
            for n, v in {
                "prefill_tok_per_s/streaming": 2000.0,
                "prefill_tok_per_s/streaming_over_monolithic": 1.2,
                "prefill_peak_bytes/streaming": 1000.0,
            }.items()]
    (out / "t.json").write_text(json.dumps(rows))
    baseline = tmp_path / "baseline.json"
    assert main([str(out), "--baseline", str(baseline),
                 "--write-baseline", "--derate", "0.5"]) == 0
    written = json.loads(baseline.read_text())
    assert written["prefill_tok_per_s/streaming"] == 1000.0               # derated
    assert written["prefill_tok_per_s/streaming_over_monolithic"] == 1.2  # exact
    assert written["prefill_peak_bytes/streaming"] == 1000.0              # exact
