"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import CacheConfig, named_policy, init_layer_cache, prefill_layer_cache
from repro.kernels.quant_pack import quant_pack
from repro.kernels.gear_decode import gear_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels import ref

pytestmark = pytest.mark.kernel


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("N,n,d", [(4, 64, 128), (2, 16, 64), (1, 64, 256), (8, 32, 32)])
def test_quant_pack_sweep(bits, N, n, d, rng):
    from repro.core import packing
    x = jax.random.normal(rng, (N, n, d), jnp.float32)
    pk, sk, zk = quant_pack(x, bits, interpret=True)
    pr, sr, zr = ref.quant_pack_ref(x, bits)
    assert jnp.allclose(sk, sr) and jnp.allclose(zk, zr)
    # The kernel and the oracle are separately-compiled XLA programs; fma/
    # fusion ordering can flip values sitting exactly on a round-half
    # boundary by ±1 code (≪0.1% of entries).  Allow exactly that jitter.
    ck = packing.unpack(pk, bits, d)
    cr = packing.unpack(pr, bits, d)
    diff = jnp.abs(ck - cr)
    assert int(diff.max()) <= 1
    assert float((diff > 0).mean()) < 1e-3


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_pack_dtypes(dtype, rng):
    from repro.core import packing
    x = jax.random.normal(rng, (2, 64, 128)).astype(dtype)
    pk, sk, zk = quant_pack(x, 4, interpret=True)
    pr, sr, zr = ref.quant_pack_ref(x, 4)
    assert jnp.allclose(sk, sr) and jnp.allclose(zk, zr)
    if dtype == jnp.float32:
        assert (pk == pr).all()
    else:
        # bf16 inputs hit round-half boundaries where fma ordering flips the
        # code by ±1 (≪0.1% of entries) — allow exactly that jitter.
        ck = packing.unpack(pk, 4, 128)
        cr = packing.unpack(pr, 4, 128)
        diff = jnp.abs(ck - cr)
        assert int(diff.max()) <= 1
        assert float((diff > 0).mean()) < 1e-3


def _cache_arrays(polname, B=2, H=2, Dh=128, S=128, n=100, nb=None):
    pol = named_policy(polname)
    if nb:
        pol = dataclasses.replace(pol, buffer_size=nb, group=min(pol.group, nb))
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=Dh, capacity=S, policy=pol)
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (B, H, n, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, H, n, Dh))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    BH = B * H
    flat = lambda x: None if x is None else x.reshape((BH,) + x.shape[2:])
    n_comp = (cache.length[0] // cfg.chunk) * cfg.chunk  # uniform slots
    common = (flat(cache.k_packed), flat(cache.k_scale), flat(cache.k_zero),
              flat(cache.v_packed), flat(cache.v_scale), flat(cache.v_zero), n_comp)
    extras = dict(
        k_a=flat(cache.k_a), k_b=flat(cache.k_b), v_a=flat(cache.v_a),
        v_b=flat(cache.v_b), k_sp_val=flat(cache.k_sp_val),
        k_sp_idx=flat(cache.k_sp_idx), v_sp_val=flat(cache.v_sp_val),
        v_sp_idx=flat(cache.v_sp_idx))
    extras = {k2: v2 for k2, v2 in extras.items() if v2 is not None}
    return cfg, common, extras


@pytest.mark.parametrize("polname", ["gear_kivi2", "gear_l_kivi2", "kivi2",
                                     "gear_kcvt4", "kcvt4", "outlier_kivi2"])
@pytest.mark.parametrize("G,Dh,S", [(2, 128, 128), (1, 64, 64), (4, 128, 192)])
def test_gear_decode_sweep(polname, G, Dh, S, rng):
    nb = 64 if S % 64 == 0 else 32
    cfg, common, extras = _cache_arrays(polname, Dh=Dh, S=S, n=S - 10, nb=nb)
    q = jax.random.normal(rng, (4, G, Dh))
    kwargs = dict(bits=cfg.policy.bits, chunk=cfg.chunk, scale_factor=Dh**-0.5)
    acc_r, m_r, l_r = ref.gear_decode_ref(q, *common, **kwargs, **extras)
    acc_k, m_k, l_k = gear_decode(q, *common, interpret=True, **kwargs, **extras)
    assert jnp.allclose(m_k[..., 0], m_r, atol=1e-4)
    out_r = acc_r / l_r[..., None]
    out_k = acc_k / l_k[..., 0:1]
    assert jnp.allclose(out_k, out_r, atol=1e-4), float(jnp.abs(out_k - out_r).max())


@pytest.mark.parametrize("polname", ["gear_kivi2", "gear_kcvt4", "kivi2"])
def test_gear_decode_ragged_sweep(polname, rng):
    """Per-row compressed extents: the ragged kernel matches the ragged
    oracle, and every row matches a solo (batch-of-one) oracle call at that
    row's scalar extent — extents cover empty (0), one chunk, a mid-cache
    chunk boundary, and the full cache."""
    nb = 32
    cfg, common, extras = _cache_arrays(polname, B=2, H=2, Dh=64, S=128,
                                        n=128, nb=nb)
    arrays = common[:-1]
    q = jax.random.normal(rng, (4, 2, 64))
    kwargs = dict(bits=cfg.policy.bits, chunk=nb, scale_factor=64**-0.5)
    n_comp = jnp.asarray([0, nb, 3 * nb, 4 * nb], jnp.int32)   # one per bh row

    acc_r, m_r, l_r = ref.gear_decode_ref(q, *arrays, n_comp, **kwargs, **extras)
    acc_k, m_k, l_k = gear_decode(q, *arrays, n_comp, interpret=True,
                                  **kwargs, **extras)
    assert jnp.allclose(m_k[..., 0], m_r, atol=1e-4)
    assert jnp.allclose(acc_k / l_k[..., 0:1], acc_r / l_r[..., None], atol=1e-4)

    # row independence: each ragged row == a solo call at its scalar extent
    for x in range(1, 4):                                      # skip the empty row
        sl = lambda a: None if a is None else a[x:x + 1]
        acc_s, m_s, l_s = ref.gear_decode_ref(
            q[x:x + 1], *[sl(a) for a in arrays], n_comp[x], **kwargs,
            **{k: sl(v) for k, v in extras.items()})
        assert jnp.allclose(acc_r[x:x + 1], acc_s, rtol=1e-6, atol=1e-6)
        assert jnp.allclose(m_r[x:x + 1], m_s) and jnp.allclose(l_r[x:x + 1], l_s)


def test_gear_decode_scalar_extent_still_accepted(rng):
    """Back-compat: a scalar n_comp broadcasts to every row."""
    cfg, common, extras = _cache_arrays("gear_kcvt4", Dh=64, S=64, n=64, nb=32)
    arrays, scalar = common[:-1], common[-1]
    q = jax.random.normal(rng, (4, 2, 64))
    kwargs = dict(bits=cfg.policy.bits, chunk=32, scale_factor=64**-0.5)
    vec = jnp.full((4,), scalar, jnp.int32)
    for fn in (ref.gear_decode_ref,
               lambda *a, **k: gear_decode(*a, interpret=True, **k)):
        acc_s, m_s, l_s = fn(q, *arrays, scalar, **kwargs, **extras)
        acc_v, m_v, l_v = fn(q, *arrays, vec, **kwargs, **extras)
        assert (acc_s == acc_v).all() and (m_s == m_v).all() and (l_s == l_v).all()


@pytest.mark.parametrize("S,Dh,bq,bk", [(128, 64, 32, 32), (256, 128, 64, 64),
                                        (64, 64, 64, 16), (128, 256, 32, 128)])
@pytest.mark.parametrize("window,prefix,cap", [(0, 0, 0.0), (48, 0, 0.0),
                                               (0, 24, 0.0), (0, 0, 20.0)])
def test_flash_prefill_sweep(S, Dh, bq, bk, window, prefix, cap, rng):
    q = jax.random.normal(rng, (2, S, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, S, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, S, Dh), jnp.float32)
    o_k = flash_prefill(q, k, v, bq=bq, bk=bk, window=window, prefix_len=prefix,
                        softcap=cap, interpret=True)
    o_r = ref.flash_prefill_ref(q, k, v, jnp.arange(S), causal=True, window=window,
                                prefix_len=prefix, softcap=cap)
    assert jnp.allclose(o_k, o_r, atol=2e-4), float(jnp.abs(o_k - o_r).max())


def test_flash_prefill_bf16(rng):
    q = jax.random.normal(rng, (2, 128, 64)).astype(jnp.bfloat16)
    k, v = q + 0.1, q - 0.1
    o_k = flash_prefill(q, k, v, bq=32, bk=32, interpret=True)
    o_r = ref.flash_prefill_ref(q, k, v, jnp.arange(128))
    assert jnp.allclose(o_k.astype(jnp.float32), o_r.astype(jnp.float32), atol=3e-2)


@pytest.mark.parametrize("mode", ["inclusive", "bonus"])
@pytest.mark.parametrize("S,Dk,Dv,chunk", [(64, 8, 16, 16), (128, 16, 16, 64),
                                           (32, 4, 8, 8)])
def test_linear_scan_kernel_sweep(mode, S, Dk, Dv, chunk, rng):
    from repro.kernels.linear_scan_kernel import linear_scan_chunked
    from repro.models.linear_scan import chunked_scan
    B, H = 2, 2
    r = jax.random.normal(rng, (B, H, S, Dk))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, S, Dk))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, H, S, Dv))
    lw = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 3), (B, H, S, Dk)))
    u = jax.random.normal(jax.random.fold_in(rng, 4), (H, Dk)) * 0.5
    y_ref, st_ref = chunked_scan(r, k, v, lw, chunk=chunk, u=u, mode=mode)
    BH = B * H
    fl = lambda x: x.reshape((BH,) + x.shape[2:])
    uu = jnp.broadcast_to(u[None], (B, H, Dk)).reshape(BH, Dk)
    y_k, st_k = linear_scan_chunked(fl(r), fl(k), fl(v), fl(lw), u=uu,
                                    chunk=chunk, mode=mode, interpret=True)
    assert jnp.allclose(y_k.reshape(B, H, S, Dv), y_ref, atol=2e-3)
    assert jnp.allclose(st_k.reshape(B, H, Dk, Dv), st_ref, atol=2e-3)


# ---------------------------------------------------------------------------
# Fused chunk compression (gear_compress)


def _lattice_chunks(key, N, nb, d, bits=4, delta=0.5):
    """Two-level {0, top} chunk batch: every quantization group, under ANY
    grouping, sees scale = delta exactly (or the eps floor for constant
    groups), and outlier removal keeps the remainder on the lattice — so
    kernel-vs-oracle parity is deterministic, with no round-half fma
    jitter to absorb, and the residual is exactly zero."""
    top = (2**bits - 1) * delta
    return top * jax.random.bernoulli(key, 0.5, (N, nb, d)).astype(jnp.float32)


@pytest.mark.parametrize("scheme,group,n_out", [
    ("per_channel", None, 1), ("per_channel", 16, 1),
    ("per_token", None, 2), ("per_token", 32, 2),
    ("per_token_group", 16, 2), ("per_channel", None, 0),
])
def test_gear_compress_bit_identical_on_lattice(scheme, group, n_out, rng):
    """The fused kernel's quant/stats/outlier outputs match the
    compress_matrix pieces EXACTLY (packing bit-identical) on lattice data,
    for both orientations, grouped stats, and the no-outlier path."""
    from repro.kernels.gear_compress import gear_compress
    x = _lattice_chunks(rng, 4, 32, 64)
    outs_k = gear_compress(x, bits=4, scheme=scheme, group=group,
                           n_out=n_out, interpret=True)
    outs_r = ref.gear_compress_ref(x, bits=4, scheme=scheme, group=group,
                                   n_out=n_out)
    for name, a, b in zip(("packed", "scale", "zero", "sp_val", "sp_idx",
                           "resid"), outs_k, outs_r):
        if b is None:
            assert a is None, name
            continue
        assert (jnp.asarray(a) == jnp.asarray(b)).all(), name
    # lossless lattice => zero residual => zero low-rank factors downstream
    assert (outs_k[5] == 0).all()


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("scheme,n_out", [("per_channel", 1), ("per_token", 2)])
def test_gear_compress_gaussian_jitter_bounded(bits, scheme, n_out, rng):
    """On arbitrary data the kernel and the oracle are separately-compiled
    programs: codes may flip ±1 on round-half boundaries (≪0.1% of entries,
    same budget as quant_pack), stats and outliers stay exact."""
    from repro.core import packing
    from repro.kernels.gear_compress import gear_compress
    x = jax.random.normal(rng, (4, 32, 64))
    pk, sk, zk, svk, sik, rk = gear_compress(x, bits=bits, scheme=scheme,
                                             n_out=n_out, interpret=True)
    pr, sr, zr, svr, sir, rr = ref.gear_compress_ref(x, bits=bits,
                                                     scheme=scheme, n_out=n_out)
    assert jnp.allclose(sk, sr) and jnp.allclose(zk, zr)
    assert (sik == sir).all() and jnp.allclose(svk, svr)
    diff = jnp.abs(packing.unpack(pk, bits, 64) - packing.unpack(pr, bits, 64))
    assert int(diff.max()) <= 1
    assert float((diff > 0).mean()) < 1e-3
    # residual differs only where a code flipped, by exactly one scale step
    assert float(jnp.abs(rk - rr).max()) <= float(sk.max()) + 1e-6


def test_gear_compress_pack_roundtrip(rng):
    """Packed lanes invert through packing.unpack to in-range codes that
    reproduce the remainder within half a quantization step."""
    from repro.core import packing
    from repro.kernels.gear_compress import gear_compress
    x = jax.random.normal(rng, (2, 16, 64))
    pk, sk, zk, _, _, _ = gear_compress(x, bits=4, scheme="per_channel",
                                        n_out=0, interpret=True)
    codes = packing.unpack(pk, 4, 64)
    assert int(codes.min()) >= 0 and int(codes.max()) <= 15
    deq = codes.astype(jnp.float32) * sk + zk      # sk/zk [N, 1, d] broadcast
    assert float(jnp.abs(deq - x).max()) <= 0.5 * float(sk.max()) + 1e-5
    assert (packing.pack(codes, 4) == pk).all()


def test_gear_compress_orientations_match_cache_layout(rng):
    """Output shapes line up with the cache's per-chunk storage layout."""
    from repro.kernels.gear_compress import gear_compress
    x = jax.random.normal(rng, (3, 32, 64))
    pk, sk, zk, sv, si, r = gear_compress(x, bits=4, scheme="per_channel",
                                          group=8, n_out=1, interpret=True)
    assert pk.shape == (3, 32, 8) and sk.shape == (3, 4, 64)
    assert sv.shape == (3, 64, 2) and r.shape == (3, 32, 64)
    pk, sk, zk, sv, si, r = gear_compress(x, bits=4, scheme="per_token",
                                          group=16, n_out=2, interpret=True)
    assert sk.shape == (3, 32, 4) and sv.shape == (3, 32, 4)


# ---------------------------------------------------------------------------
# Streaming-prefill attention pieces


@pytest.mark.parametrize("T,Dh,cap", [(16, 64, 0.0), (32, 128, 0.0), (16, 64, 20.0)])
def test_flash_prefill_block_sweep(T, Dh, cap, rng):
    from repro.kernels.flash_prefill import flash_prefill_block
    q = jax.random.normal(rng, (4, T, Dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (4, T, Dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (4, T, Dh))
    kv_len = jnp.asarray([T, T // 2, 1, 0], jnp.int32)   # full/partial/one/empty
    a_k, m_k, l_k = flash_prefill_block(q, k, v, kv_len, scale=Dh**-0.5,
                                        softcap=cap, interpret=True)
    a_r, m_r, l_r = ref.flash_block_ref(q, k, v, kv_len, scale=Dh**-0.5,
                                        softcap=cap)
    assert jnp.allclose(m_k[..., 0], m_r, atol=1e-5)
    assert jnp.allclose(l_k[..., 0], l_r, atol=1e-4)
    assert jnp.allclose(a_k, a_r, atol=1e-4)


def test_gear_hist_block_ref_matches_gear_decode_ref(rng):
    """The streaming history scorer (densified fast path) and the decode
    oracle (factored path) are the same math."""
    cfg, common, extras = _cache_arrays("gear_kcvt4", Dh=64, S=128, n=128, nb=32)
    arrays = common[:-1]
    q = jax.random.normal(rng, (4, 48, 64))     # block of G*T query rows
    kwargs = dict(bits=4, chunk=32, scale_factor=64**-0.5)
    for n_comp in (jnp.int32(0), jnp.int32(64), jnp.asarray([0, 32, 96, 128])):
        acc_a, m_a, l_a = ref.gear_decode_ref(q, *arrays, n_comp, **kwargs, **extras)
        acc_b, m_b, l_b = ref.gear_hist_block_ref(q, *arrays, n_comp, **kwargs, **extras)
        assert jnp.allclose(m_a, m_b, atol=1e-4)
        assert jnp.allclose(l_a, l_b, rtol=1e-5, atol=1e-4)
        mask = l_a[..., None] > 1e-20
        assert jnp.allclose(jnp.where(mask, acc_a, 0), jnp.where(mask, acc_b, 0),
                            rtol=1e-4, atol=1e-3)


def test_gear_attend_block_kernel_matches_oracle(rng):
    """The full streaming attention step — gear_decode history + flash
    block + two-piece merge — agrees between forced-interpret kernels and
    the jnp oracles."""
    import dataclasses as dc
    from repro.core import CacheConfig as CC
    from repro.core import named_policy as np_
    from repro.core import init_layer_cache as ilc, prefill_layer_cache as plc
    from repro.kernels import ops as kernel_ops
    pol = dc.replace(np_("gear_kcvt4"), buffer_size=16)
    cfg = CC(batch=2, kv_heads=2, head_dim=64, capacity=64, policy=pol)
    k = jax.random.normal(rng, (2, 2, 48, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 1), (2, 2, 48, 64))
    cache = plc(cfg, ilc(cfg), k, v)
    q = jax.random.normal(jax.random.fold_in(rng, 2), (2, 4, 16, 64))
    k_blk = jax.random.normal(jax.random.fold_in(rng, 3), (2, 2, 16, 64))
    v_blk = jax.random.normal(jax.random.fold_in(rng, 4), (2, 2, 16, 64))
    for n_comp, blk_len in ((32, 16), (0, 16), (48, 5)):
        o_ref = kernel_ops.gear_attend_block(cfg, cache, q, k_blk, v_blk,
                                             n_comp, blk_len, 64**-0.5)
        o_krn = kernel_ops.gear_attend_block(cfg, cache, q, k_blk, v_blk,
                                             n_comp, blk_len, 64**-0.5,
                                             force_kernel=True, interpret=True)
        valid = o_ref[:, :, :blk_len]
        assert jnp.allclose(o_krn[:, :, :blk_len], valid, atol=1e-4), (n_comp, blk_len)


def test_attention_train_flash_impl_matches_chunked(rng):
    """Satellite: the monolithic full-sequence path dispatches through the
    flash_prefill kernel (interpret mode here) and agrees with the scanned
    XLA blocks within bf16 score resolution — causal, windowed, and
    softcapped variants."""
    import dataclasses as dc
    from repro.configs.base import ModelConfig
    from repro.models import attention as attn_lib
    from repro.models.common import KeyGen
    base = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=64)
    cases = [
        (base, "global"),
        (dc.replace(base, attn_pattern="local_global", local_window=8), "local"),
        (dc.replace(base, attn_logit_softcap=20.0), "global"),
    ]
    for cfg, kind in cases:
        params = attn_lib.attn_params(cfg, KeyGen(jax.random.PRNGKey(0)))
        x = jax.random.normal(rng, (2, 48, 64), jnp.bfloat16)
        pos = jnp.arange(48, dtype=jnp.int32)
        out_c, (k_c, v_c) = attn_lib.attention_train(cfg, params, x, pos, kind)
        out_f, (k_f, v_f) = attn_lib.attention_train(cfg, params, x, pos, kind,
                                                     impl="flash-interpret")
        assert (k_c == k_f).all() and (v_c == v_f).all()   # same projections
        assert jnp.allclose(out_c.astype(jnp.float32), out_f.astype(jnp.float32),
                            atol=3e-2), kind


def test_flash_prefill_kv_repeat_matches_broadcast(rng):
    """GQA via the kv_repeat index map == explicitly broadcast K/V."""
    q = jax.random.normal(rng, (8, 64, 64), jnp.float32)        # B*Hkv*G = 8
    k = jax.random.normal(jax.random.fold_in(rng, 1), (4, 64, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (4, 64, 64))
    o_map = flash_prefill(q, k, v, bq=32, bk=32, kv_repeat=2, interpret=True)
    kb = jnp.repeat(k, 2, axis=0)
    vb = jnp.repeat(v, 2, axis=0)
    o_rep = flash_prefill(q, kb, vb, bq=32, bk=32, interpret=True)
    assert jnp.allclose(o_map, o_rep, atol=1e-6)
