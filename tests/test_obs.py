"""Serving telemetry subsystem (ISSUE 10): metrics registry, per-request
tracing, and online compression-fidelity probes.

Three layers under test:

* :mod:`repro.obs.registry` — dependency-free Counter/Gauge/Histogram with
  label sets: cardinality bounds, Prometheus bucket-edge semantics,
  clock-injected snapshot determinism, and text/JSON export round-trips;
* :mod:`repro.obs.tracing` — request-lifecycle spans and events, Chrome
  ``trace_event`` export, and the never-crash contract on unknown rids;
* the serving integration — an obs-enabled :class:`Engine` driven through
  :class:`Scheduler.run_continuous`: 100% trace coverage with statuses
  matching the audit, registry totals matching ``last_stats``, per-layer
  fidelity reports, typed :class:`PoolSnapshot` / :class:`PrefixSnapshot`
  compat, and per-RUN delta semantics of the prefix counters across
  consecutive ``run_continuous`` calls (satellite a).
"""

import dataclasses
import json

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core.policy import named_policy
from repro.models.model import build_model
from repro.obs import Observability, ObsConfig
from repro.obs.catalog import METRICS, build_registry
from repro.obs.registry import (METRICS_SCHEMA, CardinalityError, Registry,
                                parse_prometheus)
from repro.obs.tracing import TRACE_SCHEMA, Tracer
from repro.serving import (Engine, EngineConfig, FakeClock, Request,
                           RequestStatus, Scheduler)

pytestmark = pytest.mark.obs

EOS = 3
TINY = ModelConfig(name="tiny-obs", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                   vocab_size=64)


def _small():
    pol = named_policy("gear_kcvt4")
    return dataclasses.replace(pol, buffer_size=8, group=8, rank=2,
                               rank_decode=2)


_SHARED: dict = {}


def _model():
    if "model" not in _SHARED:
        m = build_model(TINY)
        _SHARED["model"] = (m, m.init(jax.random.PRNGKey(0)))
    return _SHARED["model"]


def _obs_engine():
    """One shared paged obs-on engine (jit programs are the slow part)."""
    if "engine" not in _SHARED:
        m, params = _model()
        _SHARED["engine"] = Engine(
            m, params, EngineConfig(batch=2, capacity=48, policy=_small(),
                                    eos_id=EOS, layout="paged",
                                    obs=ObsConfig(fidelity_every_n=1)))
    return _SHARED["engine"]


def _requests(n=5, seed=0, min_len=10, max_len=20):
    rng = np.random.RandomState(seed)
    budgets = [6, 3, 9, 1, 5, 7, 2][:n]
    return [Request(rid=i,
                    tokens=rng.randint(4, 64, size=rng.randint(min_len, max_len)),
                    max_new_tokens=b)
            for i, b in enumerate(budgets)]


# ---------------------------------------------------------------------------
# Registry


def test_counter_and_gauge_basics():
    r = Registry()
    c = r.counter("reqs_total", "requests", labels=("status",))
    c.inc(status="ok")
    c.inc(2.0, status="ok")
    c.inc(status="failed")
    assert c.value(status="ok") == 3.0
    assert c.value(status="failed") == 1.0
    with pytest.raises(ValueError):
        c.inc(-1.0, status="ok")
    with pytest.raises(ValueError):        # undeclared label name
        c.inc(shard="0")
    g = r.gauge("depth", "queue depth")
    g.set(4)
    g.dec()
    assert g.value() == 3.0
    # series are deterministically ordered by label values
    assert [s["labels"]["status"] for s in c.series()] == ["failed", "ok"]


def test_label_cardinality_bound():
    r = Registry()
    c = r.counter("c_total", "bounded", labels=("rid",), max_label_sets=3)
    for i in range(3):
        c.inc(rid=str(i))
    with pytest.raises(CardinalityError):
        c.inc(rid="explodes")
    c.inc(rid="1")                         # existing series still fine
    assert c.value(rid="1") == 2.0


def test_histogram_bucket_edges():
    r = Registry()
    h = r.histogram("lat_seconds", "latency", buckets=(1.0, 2.0, 5.0))
    for v in (1.0, 1.0000001, 2.0, 5.0, 7.0):   # le-INclusive edges
        h.observe(v)
    (s,) = h.series()
    by_le = {b["le"]: b["count"] for b in s["buckets"]}
    assert by_le == {1.0: 1, 2.0: 3, 5.0: 4, "+Inf": 5}   # cumulative
    assert s["count"] == 5 and s["sum"] == pytest.approx(16.0000001)
    with pytest.raises(ValueError):        # unsorted buckets
        r.histogram("bad_seconds", "x", buckets=(2.0, 1.0))


def test_registry_reregistration_and_lookup():
    r = Registry()
    c1 = r.counter("x_total", "help", labels=("a",))
    assert r.counter("x_total", "help", labels=("a",)) is c1
    with pytest.raises(ValueError):        # same name, different spec
        r.counter("x_total", "help", labels=("b",))
    with pytest.raises(ValueError):        # kind clash
        r.gauge("x_total", "help", labels=("a",))
    with pytest.raises(KeyError):
        r.get("unregistered")
    assert "x_total" in r and "nope" not in r


def test_snapshot_deterministic_under_injected_clock():
    def build():
        clock = FakeClock(100.0)
        r = Registry(clock=clock)
        c = r.counter("ops_total", "ops", labels=("kind",))
        h = r.histogram("dt_seconds", "dt", buckets=(0.1, 1.0))
        for kind, dt in (("b", 0.05), ("a", 0.5), ("b", 2.0)):
            c.inc(kind=kind)
            h.observe(dt)
            clock.advance(1.0)
        return r
    a, b = build(), build()
    assert a.to_json() == b.to_json()
    assert a.to_prometheus() == b.to_prometheus()
    assert a.snapshot()["time"] == 103.0
    assert a.snapshot()["schema"] == METRICS_SCHEMA


def test_prometheus_round_trip_with_hostile_labels():
    r = Registry()
    c = r.counter("c_total", 'he says "hi"\nand leaves', labels=("path",))
    c.inc(3, path='a"b\\c\nd')             # quote, backslash, newline
    g = r.gauge("g", "plain")
    g.set(-2.5)
    h = r.histogram("h_seconds", "hist", buckets=(0.5, 1.0))
    h.observe(0.25)
    parsed = parse_prometheus(r.to_prometheus())
    assert parsed[("c_total", (("path", 'a"b\\c\nd'),))] == 3.0
    assert parsed[("g", ())] == -2.5
    assert parsed[("h_seconds_bucket", (("le", "0.5"),))] == 1.0
    assert parsed[("h_seconds_bucket", (("le", "+Inf"),))] == 1.0
    assert parsed[("h_seconds_count", ())] == 1.0
    with pytest.raises(ValueError):
        parse_prometheus("not a sample line at all{")


def test_catalog_preregisters_every_metric():
    reg = build_registry()
    names = set(reg.names())
    assert {m.name for m in METRICS} == names
    for m in METRICS:
        assert reg.get(m.name).kind == m.kind
        assert tuple(reg.get(m.name).label_names) == tuple(m.labels)


# ---------------------------------------------------------------------------
# Tracer


def test_tracer_lifecycle_and_chrome_export():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    tr.start(7)
    tr.begin(7, "queued")
    clock.advance(1.0)
    tr.end(7)
    tr.begin(7, "prefill", attempt=1)
    tr.event(7, "fault", site="nan_chunk")
    clock.advance(2.0)
    tr.end(7)
    tr.step(7)
    tr.step(7)
    tr.finish(7, "ok")
    cov = tr.coverage([7])
    assert cov["complete"] and cov["statuses"] == {7: "ok"}
    doc = json.loads(tr.to_json())
    assert doc["schema"] == TRACE_SCHEMA
    names = [(e["name"], e["ph"]) for e in doc["traceEvents"]]
    assert ("request", "X") in names and ("prefill", "X") in names
    assert ("fault", "i") in names
    req = next(e for e in doc["traceEvents"] if e["name"] == "request")
    assert req["args"]["decode_steps"] == 2
    assert req["dur"] == pytest.approx(3e6)          # µs


def test_tracer_unknown_rid_and_duplicate_start():
    tr = Tracer(clock=FakeClock())
    # unknown rids never crash serving
    tr.begin(99, "x")
    tr.end(99)
    tr.event(99, "y")
    tr.step(99)
    tr.finish(99, "ok")
    assert tr.completed == []
    tr.start(1)
    tr.start(1)                            # resubmit: old trace kept as evidence
    tr.finish(1, "ok")
    assert [t.status for t in tr.completed] == ["abandoned", "ok"]
    cov = tr.coverage([1])
    assert not cov["complete"] and cov["duplicates"] == [1]


def test_tracer_bound_annotations_and_disabled():
    tr = Tracer(clock=FakeClock())
    tr.annotate(x=1)                       # unbound: no-op, no crash
    tr.event_bound("nope")
    with tr.span_bound("nothing"):
        pass
    tr.start(1)
    tr.begin(1, "prefill")
    tr.bind(1)
    tr.annotate(bucket_tokens=16)
    with tr.span_bound("splice"):
        pass
    tr.event_bound("quarantine")
    tr.unbind()
    tr.end(1)
    tr.finish(1, "ok")
    (t,) = tr.completed
    assert {s.name for s in t.spans} == {"prefill", "splice"}
    prefill = next(s for s in t.spans if s.name == "prefill")
    assert prefill.args["bucket_tokens"] == 16
    assert [name for name, _, _ in t.events] == ["quarantine"]

    off = Tracer(enabled=False)
    off.start(5)
    off.finish(5, "ok")
    assert off.completed == [] and off.active == {}


# ---------------------------------------------------------------------------
# Config plumbing + typed snapshots


def test_engineconfig_obs_coercion():
    kw = dict(batch=1, capacity=32, policy=_small())
    assert EngineConfig(**kw).obs is None
    assert EngineConfig(**kw, obs=False).obs is None
    assert EngineConfig(**kw, obs=True).obs == ObsConfig()
    got = EngineConfig(**kw, obs={"fidelity_every_n": 4}).obs
    assert got == ObsConfig(fidelity_every_n=4)
    with pytest.raises(ValueError):
        EngineConfig(**kw, obs=42)
    with pytest.raises(ValueError):
        ObsConfig(fidelity_every_n=-1)
    with pytest.raises(ValueError):
        ObsConfig(fidelity_budget_frac=0.0)


def test_sync_counter_delta_and_reset_clamp():
    o = Observability(ObsConfig())
    o.sync_counter("pool_admits_total", 5)
    o.sync_counter("pool_admits_total", 8)
    assert o.registry.get("pool_admits_total").value() == 8.0
    # a rebuilt pool restarts its cumulative stats at 0: the counter must
    # clamp (treat the new stream as fresh), never go backwards or crash
    o.sync_counter("pool_admits_total", 2)
    assert o.registry.get("pool_admits_total").value() == 10.0


def test_prefix_snapshot_dict_compat():
    from repro.prefixcache import PrefixCache
    pc = PrefixCache(chunk=2, budget_bytes=1 << 20)
    snap = pc.snapshot()
    assert snap["lookups"] == snap.lookups == 0
    assert snap.as_dict()["budget_bytes"] == 1 << 20
    with pytest.raises(KeyError):
        snap["not_a_field"]


# ---------------------------------------------------------------------------
# Serving integration (shared obs engine; compile-heavy)


@pytest.mark.slow
def test_end_to_end_coverage_metrics_and_fidelity():
    eng = _obs_engine()
    o = eng.obs
    o.tracer.reset()
    sched = Scheduler(eng)
    reqs = _requests()
    for r in reqs:
        sched.submit(r)
    results = sched.run_continuous()
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]

    # exactly one finished trace per submitted rid, statuses = audit truth
    cov = o.tracer.coverage([r.rid for r in reqs])
    assert cov["complete"], cov
    assert cov["statuses"] == {r.rid: str(r.status) for r in results}

    # registry totals agree with the scheduler's own accounting
    reg = o.registry
    total = sum(s["value"] for s in reg.get("serving_results_total").series())
    assert total == len(results)
    by_status = {s["labels"]["status"]: s["value"]
                 for s in reg.get("serving_results_total").series()}
    assert by_status == {k: float(v)
                         for k, v in sched.last_stats["statuses"].items()}
    assert reg.get("serving_requests_submitted_total").value() == len(reqs)
    assert reg.get("serving_decode_steps_total").value() > 0

    # fidelity probes: >= 1 sampled chunk reported on every GEAR layer
    assert o.fidelity is not None and o.fidelity.reports
    pat = len(TINY.layer_pattern)
    want = {rep_i * pat + i for rep_i in range(TINY.pattern_repeats)
            for i in o.fidelity._gear_pos}
    seen = {lr["layer"] for rp in o.fidelity.reports for lr in rp["layers"]}
    assert seen == want
    assert all(np.isfinite(lr["k_rel_err"]) and np.isfinite(lr["v_rel_err"])
               for rp in o.fidelity.reports for lr in rp["layers"])

    # typed pool snapshot rides last_stats with dict-style compat
    pool = sched.last_stats["pool"]
    assert pool["admits"] == pool.admits >= len(results)
    with pytest.raises(KeyError):
        pool["bogus"]

    # exports round-trip on the live registry
    parsed = parse_prometheus(o.to_prometheus())
    assert parsed[("serving_requests_submitted_total", ())] == len(reqs)
    snap = json.loads(o.to_json())
    assert {m["name"] for m in snap["metrics"]} == set(reg.names())


@pytest.mark.slow
def test_prefix_counters_are_per_run_deltas():
    """Satellite (a): ``last_stats`` prefix counters reset every
    ``run_continuous`` call while the registry keeps lifetime totals."""
    m, params = _model()
    clock = FakeClock()
    eng = Engine(m, params,
                 EngineConfig(batch=1, capacity=48, policy=_small(),
                              eos_id=-1, prefix_cache=True,
                              prefill_mode="streaming",
                              prefix_cache_ttl=60.0, obs=True),
                 clock=clock)
    shared = np.arange(4, 20, dtype=np.int64) % 60 + 4    # two 8-token chunks
    reqs = [np.concatenate([shared, [5 + i, 6, 7 + i]]) for i in range(3)]

    def run_once():
        sched = Scheduler(eng, clock=clock, sleep=clock.sleep)
        for i, toks in enumerate(reqs):
            sched.submit(Request(rid=run_once.rid + i, tokens=toks,
                                 max_new_tokens=2))
        run_once.rid += 100
        sched.run_continuous()
        return sched.last_stats
    run_once.rid = 0

    st1 = run_once()                      # cold: request 1 seeds the trie
    st2 = run_once()                      # warm: every request hits
    st3 = run_once()
    assert st1["prefill_toks_saved"] < st2["prefill_toks_saved"]
    # per-RUN delta: an identical warm run reports the same saving, not a
    # lifetime-cumulative doubling
    assert st2["prefill_toks_saved"] == st3["prefill_toks_saved"] > 0
    assert st3["prefix"].prefill_toks_saved == (
        st1["prefill_toks_saved"] + 2 * st2["prefill_toks_saved"])
    assert st2["prefix_expiries"] == st3["prefix_expiries"] == 0

    clock.advance(120.0)                  # past the 60s TTL
    st4 = run_once()
    assert st4["prefix_expiries"] >= 1    # this run drained stale chunks
    st5 = run_once()
    assert st5["prefix_expiries"] == 0    # delta, not lifetime
    assert st5["prefix"].expiries >= 1    # lifetime stays in the snapshot
    # the registry counter tracks the lifetime total via sync_counter
    assert (eng.obs.registry.get("prefix_expiries_total").value()
            == st5["prefix"].expiries)
