"""Hypothesis property tests on the system's core invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; CI's full lane installs it via "
           "`pip install -e .[test]`")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import gear, metrics, packing, quant, outlier
from repro.core.policy import CompressionPolicy, named_policy
from repro.models.linear_scan import chunked_scan, sequential_scan_ref

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


@given(bits=st.sampled_from([2, 4, 8]),
       rows=st.integers(1, 8), lanes=st.integers(1, 8),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_pack_unpack_identity(bits, rows, lanes, seed):
    per = 32 // bits
    codes = jax.random.randint(jax.random.PRNGKey(seed), (rows, lanes * per),
                               0, 2**bits)
    assert (packing.unpack(packing.pack(codes, bits), bits) == codes).all()


@given(seed=st.integers(0, 2**16), bits=st.sampled_from([2, 4, 8]),
       scheme=st.sampled_from(["per_channel", "per_token", "per_token_group"]))
@settings(**SETTINGS)
def test_quant_error_bounded_by_group_range(seed, bits, scheme):
    """|x − deq(q(x))| ≤ Δ/2 + eps elementwise — uniform quantizer invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, 32)) * 3
    group = 16 if scheme == "per_token_group" else None
    qt = quant.quantize(x, bits, scheme, group)
    xh = quant.dequantize(qt)
    # per-entry error bounded by half the step of its group
    if scheme == "per_channel":
        delta = (x.max(1, keepdims=True) - x.min(1, keepdims=True)) / (2**bits - 1)
    elif scheme == "per_token":
        delta = (x.max(-1, keepdims=True) - x.min(-1, keepdims=True)) / (2**bits - 1)
    else:
        xg = x.reshape(2, 16, 2, 16)
        d = (xg.max(-1, keepdims=True) - xg.min(-1, keepdims=True)) / (2**bits - 1)
        delta = jnp.broadcast_to(d, xg.shape).reshape(x.shape)
    assert (jnp.abs(x - xh) <= delta / 2 + 1e-4).all()


@given(seed=st.integers(0, 2**16), s=st.floats(0.02, 0.3),
       axis=st.sampled_from(["token", "channel"]))
@settings(**SETTINGS)
def test_outlier_exact_split(seed, s, axis):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 20, 16))
    sp, rem = outlier.filter_outliers(x, s, axis)
    assert jnp.allclose(rem + outlier.densify(sp), x, atol=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_gear_never_worse_than_quant(seed):
    """Adding error-reduction components never increases approximation error."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, 64, 32)) * (
        1 + 4 * jax.random.bernoulli(jax.random.fold_in(key, 1), 0.02, (2, 64, 32)))
    pol_q = CompressionPolicy("quant", "kivi", bits=2, group=32, buffer_size=64)
    pol_g = CompressionPolicy("gear", "kivi", bits=2, group=32, buffer_size=64)
    e_q = float(gear.approx_error(x, pol_q, "k"))
    e_g = float(gear.approx_error(x, pol_g, "k"))
    assert e_g <= e_q + 1e-3


@given(n=st.integers(256, 4096), d=st.sampled_from([1024, 4096]),
       name=st.sampled_from(["kivi2", "gear_kivi2", "gear_l_kivi2", "kcvt4"]))
@settings(**SETTINGS)
def test_kv_size_fraction_sane(n, d, name):
    pol = named_policy(name)
    f = metrics.kv_size_fraction(pol, n, d, num_heads=8, head_dim=128)
    assert 0.05 < f < 1.0
    # compressed always beats fp16; 2-bit beats that policy's own quant bytes floor
    assert f > pol.bits / 16.0 * 0.9


@given(seed=st.integers(0, 2**12), chunk=st.sampled_from([4, 8, 16]),
       mode=st.sampled_from(["inclusive", "bonus"]))
@settings(max_examples=10, deadline=None)
def test_chunked_scan_equals_sequential(seed, chunk, mode):
    key = jax.random.PRNGKey(seed)
    B, H, S, Dk, Dv = 1, 2, 32, 4, 8
    r = jax.random.normal(key, (B, H, S, Dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, Dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, Dv))
    lw = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, Dk)))
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, Dk)) * 0.3
    y1, s1 = chunked_scan(r, k, v, lw, chunk=chunk, u=u, mode=mode)
    y2, s2 = sequential_scan_ref(r, k, v, lw, u=u, mode=mode)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)


_SCHED_ENGINE = []


def _sched_engine():
    """Tiny continuous-batching engine, built once for the property below."""
    if not _SCHED_ENGINE:
        from repro.configs.base import ModelConfig
        from repro.models.model import build_model
        from repro.serving.engine import Engine, EngineConfig
        cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                          num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                          vocab_size=64)
        pol = dataclasses.replace(named_policy("gear_kcvt4"),
                                  buffer_size=8, rank=2, rank_decode=2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _SCHED_ENGINE.append(Engine(model, params, EngineConfig(
            batch=2, capacity=32, policy=pol, eos_id=-1)))
    return _SCHED_ENGINE[0]


@given(seed=st.integers(0, 2**16), n_reqs=st.integers(1, 6),
       data=st.data())
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_continuous_batching_serves_every_request_once(seed, n_reqs, data):
    """Any submit order and any budget mix: ``run_continuous`` returns every
    rid exactly once, with exactly its own budget of tokens (eos disabled)."""
    from repro.serving.scheduler import Request, Scheduler
    rng = np.random.RandomState(seed)
    budgets = [data.draw(st.integers(1, 8), label=f"budget{i}")
               for i in range(n_reqs)]
    order = data.draw(st.permutations(range(n_reqs)), label="submit_order")
    sched = Scheduler(_sched_engine())
    for i in order:
        sched.submit(Request(rid=i, tokens=rng.randint(1, 64, size=rng.randint(1, 7)),
                             max_new_tokens=budgets[i]))
    results = sched.run_continuous()
    assert sorted(r.rid for r in results) == list(range(n_reqs))
    for r in results:
        assert len(r.tokens) == budgets[r.rid], (r.rid, budgets[r.rid])
        assert r.tokens.dtype == np.int32


@pytest.mark.kernel
@given(len0=st.integers(0, 32), len1=st.integers(0, 32),
       seed=st.integers(0, 2**10))
@settings(max_examples=8, deadline=None)
def test_fused_ragged_attend_matches_jnp_per_slot(len0, len1, seed):
    """ANY pair of per-slot lengths (0 / buffer-only / chunk-boundary /
    mixed): the ragged fused path (oracle AND interpret-mode Pallas kernel)
    agrees with the per-slot jnp attend, slot by slot."""
    from repro.core import (CacheConfig, named_policy, init_layer_cache,
                            prefill_layer_cache, attend, reset_slot,
                            prefill_into_slot)
    from repro.kernels.ops import gear_attend
    key = jax.random.PRNGKey(seed)
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=8,
                              rank=2, rank_decode=2)
    B, H, DH = 2, 2, 32
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=32, policy=pol)
    cache = init_layer_cache(cfg)
    for s, n in enumerate((len0, len1)):
        if n == 0:
            cache = reset_slot(cfg, cache, s)
            continue
        ks = jax.random.normal(jax.random.fold_in(key, s), (1, H, n, DH))
        vs = jax.random.normal(jax.random.fold_in(key, 10 + s), (1, H, n, DH))
        cache = prefill_into_slot(cfg, cache, ks, vs, s)
    assert [int(x) for x in cache.length] == [len0, len1]
    q = jax.random.normal(jax.random.fold_in(key, 99), (B, H * 2, DH))
    o_fused = gear_attend(cfg, cache, q, scale=DH**-0.5)
    o_kern = gear_attend(cfg, cache, q, scale=DH**-0.5,
                         force_kernel=True, interpret=True)
    o_jnp = attend(cfg, cache, q, scale=DH**-0.5)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_fused), atol=1e-4)
    for s, n in enumerate((len0, len1)):
        if n == 0:
            assert (np.asarray(o_fused[s]) == 0).all()
        else:
            np.testing.assert_allclose(np.asarray(o_fused[s]), np.asarray(o_jnp[s]),
                                       atol=3e-2)


@given(n_prefill=st.integers(5, 40), n_decode=st.integers(0, 12),
       seed=st.integers(0, 2**10))
@settings(max_examples=8, deadline=None)
def test_cache_roundtrip_any_phase(n_prefill, n_decode, seed):
    """Streaming-buffer invariant: after ANY prefill length and ANY number of
    appended tokens, dense reconstruction matches the true KV within the
    policy's quantization error, and buffered tokens round-trip exactly."""
    from repro.core import (CacheConfig, named_policy, init_layer_cache,
                            prefill_layer_cache, append_token, dense_kv)
    key = jax.random.PRNGKey(seed)
    pol = dataclasses.replace(named_policy("gear_kcvt4"), buffer_size=16)
    B, H, DH = 1, 2, 32
    cfg = CacheConfig(batch=B, kv_heads=H, head_dim=DH, capacity=64, policy=pol)
    k = jax.random.normal(key, (B, H, n_prefill, DH))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, H, n_prefill, DH))
    cache = prefill_layer_cache(cfg, init_layer_cache(cfg), k, v)
    ks, vs = [k], [v]
    for t in range(n_decode):
        kt = jax.random.normal(jax.random.fold_in(key, 100 + t), (B, H, DH))
        vt = jax.random.normal(jax.random.fold_in(key, 200 + t), (B, H, DH))
        cache = append_token(cfg, cache, kt, vt)
        ks.append(kt[:, :, None]); vs.append(vt[:, :, None])
    total = n_prefill + n_decode
    assert (cache.length == total).all()
    k_all = jnp.concatenate(ks, axis=2)
    kh, _ = dense_kv(cfg, cache)
    rel = float(jnp.linalg.norm(kh[:, :, :total] - k_all) / jnp.linalg.norm(k_all))
    assert rel < 0.25, rel  # 4-bit GEAR bound
    # tokens still in the buffer are exact (bf16)
    nb = cfg.chunk
    n_buf = total - (total // nb) * nb
    if n_buf:
        buffered = k_all[:, :, total - n_buf:]
        np.testing.assert_allclose(np.asarray(kh[:, :, total - n_buf: total]),
                                   np.asarray(buffered), atol=2e-2)
