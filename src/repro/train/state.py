"""Train state + run configuration."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init

__all__ = ["RunConfig", "TrainState", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class RunConfig:
    total_steps: int = 1000
    warmup_steps: int = 100
    microbatches: int = 1          # gradient accumulation
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save dot outputs, recompute elementwise)
    zero1: bool = True             # shard optimizer moments over data axis
    grad_compression: str = "none"  # none | powersgd  (cross-pod axis)
    powersgd_rank: int = 8
    powersgd_min_size: int = 65536
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 200
    log_every: int = 10
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    seq_parallel: bool = False


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "opt", "step", "ef"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState
    step: jnp.ndarray
    ef: Any  # PowerSGD error feedback (tree of arrays/None) or None


def init_train_state(params: Any, run: RunConfig) -> TrainState:
    ef = None
    if run.grad_compression == "powersgd":
        from repro.optim.grad_compress import CompressorConfig, init_error_feedback
        ef = init_error_feedback(params, CompressorConfig(rank=run.powersgd_rank, min_size=run.powersgd_min_size))
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)
