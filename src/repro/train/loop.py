"""Distributed training step + host-side loop with fault tolerance.

* grad accumulation via ``lax.scan`` over microbatches (XLA overlaps the
  previous microbatch's reduce-scatter with the next's compute),
* remat (``jax.checkpoint``) on the layer-stack scan,
* ZeRO-1 optimizer-moment sharding,
* optional PowerSGD cross-pod gradient compression under partial-manual
  ``shard_map`` (pod manual, data/model left to the SPMD partitioner),
* checkpoint/restart with SIGTERM (preemption) handling, deterministic
  data replay, and elastic-rescale restore (mesh-independent checkpoints).
"""

from __future__ import annotations

import functools
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, make_batch
from repro.dist import sharding as shd
from repro.dist.compat import manual_shard_map
from repro.launch.mesh import POD, dp_axes
from repro.models.model import Model
from repro.optim.adamw import adamw_update
from repro.optim.grad_compress import CompressorConfig, compressed_psum
from repro.optim.schedule import lr_at
from repro.train.state import RunConfig, TrainState, init_train_state

__all__ = ["make_train_step", "train_state_shardings", "train_loop"]


def _microbatch(batch: Any, m: int, i: jnp.ndarray) -> Any:
    def slice_mb(x):
        mb = x.shape[0] // m
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree.map(slice_mb, batch)


def _accum_grads(model: Model, params: Any, batch: Any, run: RunConfig,
                 shard_map_safe: bool = False):
    """Mean loss/grads over ``run.microbatches`` sequential microbatches.

    ``shard_map_safe`` avoids ``lax.scan`` while-loops entirely (unrolled
    layer stack, Python-loop microbatches): XLA's SPMD partitioner aborts
    on while loops inside partially-manual shard_map regions (jaxlib
    0.4.x), which is where the PowerSGD step runs.
    """
    m = run.microbatches

    def loss_fn(p, mb):
        loss, metrics = model.loss_fn(p, mb, remat=run.remat,
                                      remat_policy=run.remat_policy,
                                      unroll_layers=shard_map_safe)
        return loss, metrics

    if m == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    if shard_map_safe:
        loss_sum = jnp.zeros(())
        grads_sum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        metrics = None
        for i in range(m):
            mb = _microbatch(batch, m, jnp.asarray(i))
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            grads_sum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                     grads_sum, grads)
            loss_sum = loss_sum + loss
        grads = jax.tree.map(lambda g: g / m, grads_sum)
        return loss_sum / m, metrics, grads

    def body(carry, i):
        loss_acc, grads_acc = carry
        mb = _microbatch(batch, m, i)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        grads_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
        return (loss_acc + loss, grads_acc), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads_sum), metrics = jax.lax.scan(
        body, (jnp.zeros(()), zeros), jnp.arange(m))
    grads = jax.tree.map(lambda g: g / m, grads_sum)
    metrics = jax.tree.map(lambda x: x[-1], metrics)
    return loss_sum / m, metrics, grads


def make_train_step(model: Model, mesh, run: RunConfig,
                    state_shardings, batch_shardings) -> Callable:
    """Build the jitted (state, batch) -> (state, metrics) step."""

    def opt_update(state: TrainState, grads, loss, metrics, ef=None):
        lr = lr_at(state.step, peak=run.optimizer.lr_peak,
                   total_steps=run.total_steps, warmup=run.warmup_steps,
                   kind=model.cfg.lr_schedule)
        new_params, new_opt, om = adamw_update(run.optimizer, grads, state.opt,
                                               state.params, lr)
        metrics = dict(metrics, loss=loss, lr=lr, **om)
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1,
                          ef=ef if ef is not None else state.ef), metrics

    if run.grad_compression == "powersgd" and POD in mesh.axis_names:
        ccfg = CompressorConfig(rank=run.powersgd_rank, axis=POD,
                                min_size=run.powersgd_min_size)

        def step(state: TrainState, batch):
            def podwise(params, ef, pod_batch):
                loss, metrics, grads = _accum_grads(model, params, pod_batch, run,
                                                    shard_map_safe=True)
                key = jax.random.fold_in(jax.random.PRNGKey(17), state.step)
                grads, new_ef, cbytes = compressed_psum(grads, ef, ccfg, key)
                loss = jax.lax.pmean(loss, POD)
                return loss, metrics, grads, new_ef, cbytes

            in_specs = (P(), P(), P(POD))
            out_specs = (P(), P(), P(), P(), P())
            loss, metrics, grads, new_ef, cbytes = manual_shard_map(
                podwise, mesh, in_specs, out_specs, manual_axes={POD},
            )(state.params, state.ef, batch)
            new_state, metrics = opt_update(state, grads, loss, metrics, ef=new_ef)
            metrics.update({k: v for k, v in cbytes.items()})
            return new_state, metrics
    else:
        def step(state: TrainState, batch):
            loss, metrics, grads = _accum_grads(model, state.params, batch, run)
            return opt_update(state, grads, loss, metrics)

    return jax.jit(step,
                   in_shardings=(state_shardings, batch_shardings),
                   out_shardings=(state_shardings, None),
                   donate_argnums=(0,))


def train_state_shardings(cfg: ModelConfig, mesh, state: Any, run: RunConfig):
    """Shardings for the TrainState pytree (ZeRO-1 moments if enabled)."""
    pspec = shd.param_pspecs(cfg, state.params, mesh)
    opt_spec = shd.zero1_pspecs(cfg, state.params, mesh) if run.zero1 else pspec
    ef_spec = jax.tree.map(lambda x: P(*(None,) * x.ndim), state.ef) if state.ef is not None else None

    def to_shard(tree):
        return shd.shardings_for(mesh, tree)

    from repro.train.state import TrainState as TS
    from repro.optim.adamw import AdamWState
    return TS(
        params=to_shard(pspec),
        opt=AdamWState(mu=to_shard(opt_spec), nu=to_shard(opt_spec),
                       count=NamedSharding(mesh, P())),
        step=NamedSharding(mesh, P()),
        ef=to_shard(ef_spec) if ef_spec is not None else None,
    )


# ---------------------------------------------------------------------------
# Host loop with fault tolerance


class _Preemption:
    """SIGTERM → finish the current step, checkpoint, exit cleanly."""

    def __init__(self):
        self.flagged = False
        try:
            signal.signal(signal.SIGTERM, self._handle)
        except ValueError:
            pass  # not on main thread (tests)

    def _handle(self, *_):
        self.flagged = True


def train_loop(model: Model, mesh, run: RunConfig, data_cfg: DataConfig,
               steps: int | None = None, log_fn=print) -> TrainState:
    """Run (or resume) training; returns the final state."""
    cfg = model.cfg
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        state = init_train_state(params, run)
        st_shard = train_state_shardings(cfg, mesh, state, run)
        state = jax.device_put(state, st_shard)

        start = ckpt_lib.latest_step(run.ckpt_dir)
        if start is not None:
            state = ckpt_lib.restore(run.ckpt_dir, start, state, st_shard)
            log_fn(f"[restore] resumed from step {start}")

        abstract_batch = jax.eval_shape(lambda: make_batch(data_cfg, cfg, 0))
        b_shard = shd.shardings_for(mesh, shd.batch_pspecs(cfg, abstract_batch, mesh))
        step_fn = make_train_step(model, mesh, run, st_shard, b_shard)

        pre = _Preemption()
        total = steps or run.total_steps
        t0 = time.time()
        while int(state.step) < total:
            s = int(state.step)
            batch = jax.device_put(make_batch(data_cfg, cfg, s), b_shard)
            state, metrics = step_fn(state, batch)
            if s % run.log_every == 0:
                m = {k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0}
                log_fn(f"[step {s}] " + " ".join(f"{k}={v:.4g}" for k, v in sorted(m.items()))
                       + f" ({time.time()-t0:.1f}s)")
            if run.ckpt_every and s > 0 and s % run.ckpt_every == 0:
                ckpt_lib.save_async(run.ckpt_dir, s, state)
            if pre.flagged:
                log_fn("[preempt] SIGTERM received — checkpointing and exiting")
                ckpt_lib.save(run.ckpt_dir, int(state.step), state)
                break
        ckpt_lib.wait_pending()
        return state
