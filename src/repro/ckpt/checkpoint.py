"""Sharded, mesh-independent, atomic checkpointing.

Layout:  <dir>/step_<N>/
             manifest.json       — tree structure, shapes, dtypes, hashes
             arr_<i>.npy         — one file per leaf (global array)
             _COMMITTED          — written last; restore ignores dirs without it

Fault-tolerance properties:
* **atomic**: manifest + leaves land in a temp dir, renamed into place, and
  the _COMMITTED marker is written last — a preempted save can never be
  half-restored.
* **mesh-independent**: leaves are stored as *global* arrays; restore
  re-shards onto whatever mesh the restarted job brings up (elastic rescale).
* **async**: ``save_async`` hands the host copy to a background thread so
  the train loop resumes immediately.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_PENDING: list[threading.Thread] = []


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    return _write(ckpt_dir, step, host, treedef)


def save_async(ckpt_dir: str, step: int, tree: Any) -> None:
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]  # sync copy, async write
    t = threading.Thread(target=_write, args=(ckpt_dir, step, host, treedef), daemon=True)
    t.start()
    _PENDING.append(t)


def wait_pending() -> None:
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _write(ckpt_dir: str, step: int, host_leaves, treedef) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, arr in enumerate(host_leaves):
        path = os.path.join(tmp, f"arr_{i}.npy")
        np.save(path, arr)
        manifest["leaves"].append({
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    shutil.rmtree(final, ignore_errors=True)
    os.replace(tmp, final)
    with open(os.path.join(final, "_COMMITTED"), "w") as f:
        f.write("ok")
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "_COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any, shardings: Any | None = None) -> Any:
    """Load a checkpoint and (optionally) reshard onto a new mesh."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    assert len(leaves) == len(manifest["leaves"]), "checkpoint/tree mismatch"
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (leaf, meta, shd) in enumerate(zip(leaves, manifest["leaves"], shard_leaves)):
        arr = np.load(os.path.join(d, f"arr_{i}.npy"))
        if hashlib.sha1(arr.tobytes()).hexdigest() != meta["sha1"]:
            raise IOError(f"checkpoint leaf {i} corrupt")
        out.append(jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
