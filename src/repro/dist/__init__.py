"""Distributed partitioning layer.

``repro.dist.sharding`` holds the SPMD sharding rules (PartitionSpec
legalization + pytree rules for params / optimizer state / batches / GEAR
caches); ``repro.dist.compat`` papers over ``shard_map`` API drift between
jax releases.
"""

from repro.dist import compat, sharding
from repro.dist.sharding import (
    batch_pspecs,
    cache_pspecs,
    fit_spec,
    param_pspecs,
    shardings_for,
    zero1_pspecs,
)

__all__ = [
    "compat",
    "sharding",
    "fit_spec",
    "param_pspecs",
    "zero1_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "shardings_for",
]
