"""SPMD partitioning rules for params, optimizer state, batches, and caches.

Everything here produces :class:`~jax.sharding.PartitionSpec` pytrees (or
:class:`~jax.sharding.NamedSharding` pytrees via :func:`shardings_for`);
nothing touches device state, so the module is safe to import before jax
initializes its backends (the dry-run forces a 512-device topology first).

Design rules:

* **Proposals, then legalization.**  The per-leaf rules below *propose* a
  layout (megatron-style column/row splits for projections, vocab-split
  embeddings, batch/heads splits for GEAR cache buffers); every proposal is
  passed through :func:`fit_spec`, which checks divisibility against the
  concrete shape and the live mesh and migrates / shrinks / drops axes that
  do not fit.  Call sites therefore never have to special-case "the smoke
  config has 2 kv heads but the mesh has 4 model shards".
* **Mesh-shape ducks.**  ``fit_spec`` only reads ``mesh.shape`` (a mapping
  of axis name to size), so tests can pass a stub instead of building a
  real device mesh.
* **Layout, not semantics.**  Under ``jit`` a sharding is a layout hint;
  any legal spec computes the same values.  Migrating a split to a
  different dim (e.g. vocab -> d_model when the vocab is prime) is
  therefore always safe, and :func:`cache_pspecs` opts out of migration
  only to keep cache layouts predictable across policies.
"""

from __future__ import annotations

import math
from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import MODEL, dp_axes

__all__ = [
    "fit_spec",
    "param_pspecs",
    "zero1_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "shardings_for",
]


# ---------------------------------------------------------------------------
# Spec legalization


def _axes_of(entry) -> tuple[str, ...]:
    """Normalize one PartitionSpec entry to a tuple of axis names."""
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def _entry_of(axes: tuple[str, ...]):
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return axes


def _longest_fitting_prefix(axes: tuple[str, ...], dim: int,
                            mesh_shape: dict) -> tuple[str, ...]:
    """Longest prefix of ``axes`` whose combined size divides ``dim``.

    A zero-size dim divides everything (0 % n == 0), matching XLA: sharding
    an empty dim is legal and free.
    """
    for end in range(len(axes), 0, -1):
        prefix = axes[:end]
        if dim % math.prod(mesh_shape[a] for a in prefix) == 0:
            return prefix
    return ()


def fit_spec(pspec: P, shape: Sequence[int], mesh, *, migrate: bool = True) -> P:
    """Legalize ``pspec`` against a concrete ``shape`` on ``mesh``.

    For each sharded dim whose size the assigned mesh axes do not divide:

    1. keep the longest prefix of the axis group that still divides the dim
       (a multi-axis group degrades gracefully instead of all-or-nothing),
    2. migrate the remaining axes to the first unsharded dim they divide
       (unless ``migrate=False``),
    3. drop whatever still does not fit (replicate).

    Axis names absent from the mesh are dropped up front; specs shorter
    than ``len(shape)`` are padded with ``None``.  The result is always a
    spec ``jax.NamedSharding(mesh, spec)`` accepts for ``shape``.
    """
    mesh_shape = dict(mesh.shape)
    entries = [_axes_of(e) for e in tuple(pspec)]
    if len(entries) > len(shape):
        raise ValueError(f"spec {pspec} has more entries than shape {tuple(shape)}")
    entries += [()] * (len(shape) - len(entries))
    entries = [tuple(a for a in e if a in mesh_shape) for e in entries]

    out: list[tuple[str, ...]] = [()] * len(shape)
    used: set[str] = set()
    homeless: list[tuple[str, ...]] = []
    for i, axes in enumerate(entries):
        if not axes:
            continue
        keep = _longest_fitting_prefix(axes, shape[i], mesh_shape)
        out[i] = keep
        used.update(keep)
        rest = axes[len(keep):]
        if rest:
            homeless.append(rest)

    if migrate:
        queue = list(homeless)
        while queue:
            axes = tuple(a for a in queue.pop(0) if a not in used)
            if not axes:
                continue
            free = [i for i in range(len(shape)) if not out[i]]
            # prefer a dim that takes the whole group, else the best prefix
            target, placed = None, ()
            for i in free:
                fit = _longest_fitting_prefix(axes, shape[i], mesh_shape)
                if fit == axes:
                    target, placed = i, fit
                    break
                if len(fit) > len(placed):
                    target, placed = i, fit
            if target is None or not placed:
                continue
            out[target] = placed
            used.update(placed)
            rest = axes[len(placed):]
            if rest:  # a partially-placed group keeps looking for a home
                queue.append(rest)

    return P(*[_entry_of(e) for e in out])


# ---------------------------------------------------------------------------
# Parameter rules


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
    return names


# Column-split projections: shard the output features (last dim) over MODEL.
_COL_SPLIT = {
    "wq", "wk", "wv", "wg", "wr",          # attention / rwkv time-mix
    "w_up", "w_gate",                       # mlp + moe expert up/gate
    "w_in", "w_bcdt",                       # ssm in-projections
    "mix_lora_a",                           # rwkv token-shift lora
    "lm_head",
}
# Row-split projections: shard the input features (second-to-last dim) over
# MODEL, so the matmul contracts over the sharded dim (megatron pairing).
_ROW_SPLIT = {"wo", "w_down", "w_out"}


def _param_rule(names: list[str], shape: tuple[int, ...]) -> list:
    """Propose per-dim mesh axes for one param leaf.

    Leaves that live under ``blocks`` carry a leading layer-stack dim [R];
    all rules therefore address trailing dims (negative indices).
    """
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    nd = len(shape)
    ent: list = [None] * nd
    if nd < 2:
        return ent  # norms, biases, per-head scalars: replicate
    if name == "embed":
        ent[nd - 2] = MODEL  # vocab dim ([V, d] text, [K, V, d] audio)
        return ent
    # rwkv channel-mix wv is the down projection [ff, d] (wv elsewhere is a
    # column-split attention projection).
    row = name in _ROW_SPLIT or (parent == "cm" and name == "wv")
    if row:
        ent[nd - 2] = MODEL
    elif name in _COL_SPLIT:
        ent[nd - 1] = MODEL
    return ent


def param_pspecs(cfg, params: Any, mesh) -> Any:
    """Model-parallel PartitionSpec pytree for a parameter pytree.

    Attention/MLP projections get megatron column/row splits, MoE expert
    stacks split on the expert hidden dim, embeddings on the vocab dim —
    each legalized against the actual leaf shape, so ragged dims (prime
    vocab, few kv heads) fall back to a divisible dim or replication.
    """
    del cfg  # rules are name/shape driven; cfg kept for API stability

    def spec(path, leaf):
        ent = _param_rule(_path_names(path), leaf.shape)
        return fit_spec(P(*ent), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params)


def zero1_pspecs(cfg, params: Any, mesh) -> Any:
    """ZeRO-1 specs for optimizer moments: param spec + a data-axes split.

    Each moment leaf keeps its model-parallel layout and is additionally
    sharded over the data-parallel axes (``pod`` folds into DP) on its
    largest replicated dim, so Adam moments cost ``1/|dp|`` of the memory
    of the replicated baseline.  Leaves with no divisible dim stay at the
    param spec — the checkpoint layer stores global arrays either way.
    """
    base = param_pspecs(cfg, params, mesh)
    dp = dp_axes(mesh)
    if not dp:
        return base
    dp_size = math.prod(dict(mesh.shape)[a] for a in dp)

    def add_dp(leaf, ps):
        entries = [_axes_of(e) for e in tuple(ps)]
        entries += [()] * (len(leaf.shape) - len(entries))
        free = [i for i, e in enumerate(entries) if not e]
        for i in sorted(free, key=lambda i: -leaf.shape[i]):
            if leaf.shape[i] % dp_size == 0 and leaf.shape[i] > 0:
                entries[i] = tuple(dp)
                break
        return fit_spec(P(*[_entry_of(e) for e in entries]), leaf.shape, mesh,
                        migrate=False)

    return jax.tree.map(add_dp, params, base,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch + cache rules


def batch_pspecs(cfg, batch: Any, mesh) -> Any:
    """Shard every batch leaf's leading (batch) dim over the DP axes."""
    del cfg
    dp = dp_axes(mesh)

    def spec(leaf):
        ent = [None] * leaf.ndim
        if dp and leaf.ndim:
            ent[0] = tuple(dp)
        return fit_spec(P(*[e if e else None for e in ent]), leaf.shape, mesh,
                        migrate=False)

    return jax.tree.map(spec, batch)


def cache_pspecs(cfg, cache_abs: Any, mesh, batch: int) -> Any:
    """GEAR-aware layouts for the serving cache pytree.

    Cache leaves are stacked over layer-pattern repeats: ``[R, B, H, ...]``
    for the quantized pack / scale / zero arrays, low-rank A/B factors,
    outlier COO value+index buffers, and the fp16 streaming buffer (RWKV /
    SSM states are ``[R, B, ...]``).  The repeat dim R is scanned over and
    stays replicated; the batch dim shards over the DP axes and the kv-head
    dim over MODEL.  ``migrate=False``: where a dim does not divide (e.g. 2
    kv heads on a 4-way model axis) the leaf is replicated on that dim
    rather than sharded somewhere surprising — chunk/COO index arithmetic
    stays position-local either way, but layouts stay uniform across the
    policy zoo (quant-only, +lowrank, +sparse, fp16, window).

    Slot-splice invariant (continuous batching, DESIGN.md): the engine
    donates the cache tree and writes one batch row at a traced offset
    (``dynamic_update_slice_in_dim`` over axis 1) when splicing a request
    into a freed slot.  That stays legal under SPMD because every leaf
    either shards axis 1 over exactly the DP axes or replicates it — never a
    mixed layout.  Per-slot lengths (``length`` [R, B]) fall outside the
    ``len(shape) >= 3`` rule and stay replicated: the cheap per-slot masks
    are recomputed on every shard rather than paying a collective; the
    window cache's ``pos`` [R, B, W] shards its batch dim like the K/V it
    masks.
    """
    dp = dp_axes(mesh)
    kv_heads = cfg.num_kv_heads

    def spec(leaf):
        shape = leaf.shape
        ent: list = [None] * len(shape)
        if len(shape) >= 3 and shape[1] == batch:
            if dp:
                ent[1] = tuple(dp)
            if len(shape) >= 4 and shape[2] == kv_heads:
                ent[2] = MODEL
        return fit_spec(P(*ent), shape, mesh, migrate=False)

    return jax.tree.map(spec, cache_abs)


# ---------------------------------------------------------------------------
# Spec -> sharding


def shardings_for(mesh, pspecs: Any) -> Any:
    """Map a PartitionSpec pytree to a NamedSharding pytree on ``mesh``."""
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
