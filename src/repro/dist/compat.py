"""shard_map across jax API generations.

The train loop runs PowerSGD under a *partially manual* ``shard_map``: the
``pod`` axis is manual (the compressor issues explicit ``pmean`` over it)
while ``data``/``model`` stay with the SPMD partitioner.  The spelling of
"manual only over these axes" has changed across jax releases:

* newer jax: ``jax.shard_map(..., axis_names={...}, check_vma=False)``
* jax 0.4.x: ``jax.experimental.shard_map.shard_map(..., auto=<complement>,
  check_rep=False)``

:func:`manual_shard_map` accepts the *manual* axis set and picks whichever
spelling the installed jax provides (by signature inspection, so a genuine
``TypeError`` from bad caller arguments is never masked).
"""

from __future__ import annotations

import inspect
from typing import Iterable

import jax

__all__ = ["manual_shard_map"]


def manual_shard_map(fn, mesh, in_specs, out_specs, manual_axes: Iterable[str]):
    """``shard_map(fn)`` manual over ``manual_axes``, auto over the rest."""
    manual = set(manual_axes)
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    if "axis_names" in params:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=manual, check_vma=False)
    auto = frozenset(mesh.axis_names) - manual
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False, auto=auto)
