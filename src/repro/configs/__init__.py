"""Config registry: ``get_config("gemma3-12b")`` and reduced smoke variants."""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES

_MODULES = {
    "gemma3-12b": "gemma3_12b",
    "minicpm-2b": "minicpm_2b",
    "gemma-2b": "gemma_2b",
    "starcoder2-3b": "starcoder2_3b",
    "paligemma-3b": "paligemma_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "musicgen-medium": "musicgen_medium",
    "hymba-1.5b": "hymba_1p5b",
    "rwkv6-3b": "rwkv6_3b",
    "llama2-7b": "llama2_7b",
}

ARCHS = tuple(k for k in _MODULES if k != "llama2-7b")
ALL_ARCHS = tuple(_MODULES)

# archs for which long_500k runs (sub-quadratic families; see DESIGN.md)
LONG_CONTEXT_ARCHS = ("gemma3-12b", "hymba-1.5b", "rwkv6-3b")


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(name)
    unit = len(cfg.layer_pattern)
    overrides = dict(
        num_layers=unit * 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
    )
    if cfg.moe:
        overrides.update(num_experts=4, moe_top_k=min(cfg.moe_top_k, 2), moe_d_ff=64)
    if cfg.modality == "vlm":
        overrides.update(num_prefix_tokens=8)
    if cfg.rwkv:
        overrides.update(num_heads=4, num_kv_heads=4)
    return dataclasses.replace(cfg, **overrides)


def shapes_for(name: str) -> tuple[ShapeConfig, ...]:
    """The assigned input shapes that apply to this arch (skips noted in DESIGN.md)."""
    cfg = get_config(name)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if name in LONG_CONTEXT_ARCHS:
        out.append(SHAPES["long_500k"])
    return tuple(out)


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "ALL_ARCHS",
    "LONG_CONTEXT_ARCHS", "get_config", "smoke_config", "shapes_for",
]
