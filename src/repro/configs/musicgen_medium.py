"""musicgen-medium — decoder-only over EnCodec tokens (4 codebooks, delay
pattern); frontend is a stub providing frame embeddings. [arXiv:2306.05284; hf]

Adaptation note: the original uses learned positional embeddings and
LayerNorm; we keep LayerNorm and use RoPE for position (TPU-idiomatic, noted
in DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    mlp_kind="gelu_mlp", norm="layernorm",
    modality="audio", num_codebooks=4, tie_embeddings=False,
)
