"""starcoder2-3b — dense GQA kv=2, LayerNorm + non-gated GELU MLP, RoPE.
[arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49_152,
    mlp_kind="gelu_mlp", norm="layernorm",
)
