"""qwen3-moe-235b-a22b — MoE 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-235B-A22B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151_936,
    mlp_kind="swiglu", qk_norm=True,
    moe=True, num_experts=128, moe_top_k=8, moe_d_ff=1536,
    tie_embeddings=False,
)
