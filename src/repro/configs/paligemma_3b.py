"""paligemma-3b — SigLIP stub + gemma-2b backbone, prefix-LM over 256 image
tokens. [arXiv:2407.07726; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257_216,
    mlp_kind="geglu", modality="vlm", num_prefix_tokens=256,
)
