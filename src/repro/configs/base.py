"""Model / run configuration dataclasses covering every assigned arch."""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    mlp_kind: str = "swiglu"       # swiglu | geglu | gelu_mlp
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    qk_norm: bool = False          # qwen3 / gemma3
    attn_logit_softcap: float = 0.0
    attn_pattern: str = "global"   # global | local_global
    local_window: int = 1024
    pattern_locals: int = 5        # locals per global in local_global pattern
    # --- moe ---
    moe: bool = False
    num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden (falls back to d_ff)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- ssm / hybrid / rwkv ---
    ssm: bool = False              # mamba-style selective SSM branch
    ssm_state: int = 16
    ssm_conv: int = 4
    hybrid_parallel: bool = False  # hymba: attn ∥ ssm heads in one block
    rwkv: bool = False             # attention-free RWKV6 (Finch)
    # --- modality stubs ---
    modality: str = "text"         # text | vlm | audio
    num_prefix_tokens: int = 0     # paligemma image tokens (prefix-LM, bidirectional)
    num_codebooks: int = 0         # musicgen EnCodec codebooks
    # --- training ---
    tie_embeddings: bool = True
    lr_schedule: str = "cosine"    # cosine | wsd (minicpm)
    max_seq_len: int = 131072

    def __post_init__(self):
        if self.moe and not self.num_experts:
            raise ValueError("moe requires num_experts")
        if self.rwkv and self.ssm:
            raise ValueError("rwkv and ssm are exclusive")

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """Per-layer attention kinds within one repeating pattern unit."""
        if self.rwkv:
            return ("rwkv",)
        if self.attn_pattern == "local_global":
            return ("local",) * self.pattern_locals + ("global",)
        return ("global",)

    @property
    def pattern_repeats(self) -> int:
        unit = len(self.layer_pattern)
        if self.num_layers % unit:
            raise ValueError(f"{self.name}: {self.num_layers} layers not divisible by pattern {unit}")
        return self.num_layers // unit

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline N."""
        d, dff, L = self.d_model, self.d_ff, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            # time-mix: r,k,v,g,o + decay lora + token-shift mixes; channel-mix
            tm = d * d * 5 + d * 64 * 2 + d * 6
            cm = 2 * d * dff
            return emb + L * (tm + cm + 2 * d)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.moe:
            up_gate = 2 if self.mlp_kind in ("swiglu", "geglu") else 1
            ff_e = self.expert_ff
            moe_p = self.num_experts * (up_gate + 1) * d * ff_e + d * self.num_experts
            if self.shared_expert:
                moe_p += (up_gate + 1) * d * ff_e
            block = attn + moe_p
        else:
            up_gate = 2 if self.mlp_kind in ("swiglu", "geglu") else 1
            block = attn + (up_gate + 1) * d * dff
        if self.ssm:
            dss = d  # ssm branch operating width
            block += 2 * d * dss + dss * self.ssm_conv + dss * (2 * self.ssm_state + 2) + dss * d
        return emb + L * block

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        up_gate = 2 if self.mlp_kind in ("swiglu", "geglu") else 1
        ff_e = self.expert_ff
        dense_moe = self.num_experts * (up_gate + 1) * d * ff_e
        active_moe = self.moe_top_k * (up_gate + 1) * d * ff_e
        return self.param_count() - L * (dense_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
