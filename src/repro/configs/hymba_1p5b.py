"""hymba-1.5b — hybrid: parallel attention + Mamba heads in each block,
ssm_state=16. [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32_001,
    mlp_kind="swiglu",
    ssm=True, ssm_state=16, hybrid_parallel=True,
    max_seq_len=524_288,
)
