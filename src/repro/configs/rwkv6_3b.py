"""rwkv6-3b (Finch) — attention-free, data-dependent decay linear recurrence.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65_536,
    mlp_kind="rwkv_cm", rwkv=True,
    max_seq_len=524_288,
)
