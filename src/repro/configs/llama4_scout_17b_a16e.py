"""llama4-scout-17b-16e — MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202_048,
    mlp_kind="swiglu",
    moe=True, num_experts=16, moe_top_k=1, moe_d_ff=8192, shared_expert=True,
    tie_embeddings=False,
)
