"""llama2-7b — the paper's primary evaluation model (Section 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=32_000,
    mlp_kind="swiglu", tie_embeddings=False,
)
