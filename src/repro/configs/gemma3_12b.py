"""gemma3-12b — dense, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-12b-pt family; assignment tier: unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262_144,
    mlp_kind="geglu", norm="rmsnorm", rope_theta=1_000_000.0,
    qk_norm=True, attn_pattern="local_global", local_window=1024, pattern_locals=5,
    max_seq_len=524_288,
)
