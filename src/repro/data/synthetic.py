"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, shard), so a restarted or
elastically-rescaled job replays the exact token stream — the property the
fault-tolerance story depends on (DESIGN.md §6).  The generator produces
Zipf-ish token draws with short-range repetition structure so losses are
learnable (benchmarks that train a small model rely on that).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["DataConfig", "make_batch", "host_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    zipf_alpha: float = 1.1
    repeat_p: float = 0.3  # probability a token copies one from 8 back


def _tokens(key, cfg: DataConfig, shape) -> jnp.ndarray:
    # Zipf via inverse-CDF on uniform; learnable short-range structure by
    # rewriting some positions with the token 8 steps earlier.
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.uniform(k1, shape, jnp.float32, 1e-6, 1.0)
    ranks = jnp.clip((u ** (-1.0 / (cfg.zipf_alpha - 1.0 + 1e-6)) - 1.0), 0,
                     cfg.vocab_size - 1).astype(jnp.int32)
    toks = ranks % cfg.vocab_size
    rep = jax.random.bernoulli(k2, cfg.repeat_p, shape)
    rolled = jnp.roll(toks, 8, axis=-1)
    return jnp.where(rep, rolled, toks)


def make_batch(cfg: DataConfig, model_cfg: ModelConfig, step: int) -> dict:
    """Global batch for a given step (works under jit via fold_in)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S = cfg.global_batch, cfg.seq_len
    if model_cfg.modality == "audio":
        return {"tokens": _tokens(key, cfg, (B, S, model_cfg.num_codebooks))}
    if model_cfg.modality == "vlm":
        p = model_cfg.num_prefix_tokens
        k1, k2 = jax.random.split(key)
        return {
            "tokens": _tokens(k1, cfg, (B, S - p)),
            "img_embeds": jax.random.normal(k2, (B, p, model_cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": _tokens(key, cfg, (B, S))}


def host_batch(cfg: DataConfig, model_cfg: ModelConfig, step: int) -> dict:
    """NumPy version for the host-side loader (no device allocation)."""
    return jax.tree.map(np.asarray, jax.device_get(make_batch(cfg, model_cfg, step)))
