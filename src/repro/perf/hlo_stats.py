"""Parse compiled HLO text for collective traffic and remat statistics.

``collective_bytes`` sums the **operand** sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(operand shapes resolved through an instruction-definition table built from
the whole module), per the roofline methodology in EXPERIMENTS.md.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_stats", "dtype_bytes", "op_histogram"]

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

# `%name = f32[8,128]{1,0} op-name(...)`  /  `name.1 = (f32[..], ..) tuple(..)`
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
                     r"([\w\-]+)\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")


def dtype_bytes(dt: str) -> float:
    return _DTYPE_BYTES.get(dt, 4)


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES and dt != "pred":
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * dtype_bytes(dt)
    return total


def collective_stats(hlo_text: str) -> dict:
    """Returns {op_kind: {count, operand_bytes}} + totals."""
    # instruction table: name -> result shape string
    shapes: dict[str, str] = {}
    defs: list[tuple[str, str, str, str]] = []  # (name, shape, op, argstr)
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=\n]*?\]\S*)\s+([\w\-]+)\((.*)$",
            hlo_text, re.M):
        name, shape, op, rest = m.groups()
        shapes[name] = shape
        defs.append((name, shape, op, rest))

    stats: dict[str, dict] = defaultdict(lambda: {"count": 0, "operand_bytes": 0.0,
                                                  "result_bytes": 0.0})
    for name, shape, op, rest in defs:
        kind = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        argstr = rest.split(")", 1)[0]
        ob = 0.0
        for om in _OPERAND_RE.finditer(argstr):
            opname = om.group(1)
            if opname in shapes:
                ob += _shape_bytes(shapes[opname])
        if ob == 0.0:          # fallback: result size
            ob = _shape_bytes(shape)
        stats[kind]["count"] += 1
        stats[kind]["operand_bytes"] += ob
        stats[kind]["result_bytes"] += _shape_bytes(shape)

    total = sum(v["operand_bytes"] for v in stats.values())
    out = {k: dict(v) for k, v in stats.items()}
    out["total_operand_bytes"] = total
    out["total_count"] = sum(v["count"] for k, v in stats.items() if k in COLLECTIVES)
    return out


def op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    """Instruction-kind histogram (remat shows up as duplicated fusions)."""
    counts: dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*\(?[a-z0-9]+\[[^\]]*\][^ ]*\s+([\w\-]+)\(", hlo_text):
        counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
