"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute    = HLO_FLOPs / (chips · 197e12)         [bf16 peak / chip]
    memory     = HLO_bytes / (chips · 819e9)          [HBM bw / chip]
    collective = coll_operand_bytes / (chips · 50e9)  [ICI per link]

The dominant term is the bottleneck; roofline fraction for the compute
term = compute / max(all terms).  MODEL_FLOPS uses 6·N·D (train) or
2·N_active per decoded token (serve), with N from the analytic param count.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["HW", "RooflineTerms", "roofline", "model_flops"]

HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip (v5e)
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per ICI link
}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time (no overlap assumption = max of terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: useful compute time / bound step time."""
        useful = self.model_flops / (self.chips * HW["peak_flops"])
        return useful / max(self.step_time_s, 1e-30)

    @property
    def flops_efficiency(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / max(self.hlo_flops, 1e-30)

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "flops_eff": self.flops_efficiency,
            "roofline_frac": self.compute_fraction,
        }


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int, model_flops_total: float) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops / (chips * HW["peak_flops"]),
        memory_s=hlo_bytes / (chips * HW["hbm_bw"]),
        collective_s=collective_bytes / (chips * HW["ici_bw"]),
        chips=chips,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops_total,
    )


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for one step of this cell."""
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention reads over each layer's
    # effective context (full cache for global layers, window for locals,
    # zero for attention-free recurrent archs).
    if cfg.rwkv:
        kv_read = 0.0
    else:
        per_unit = 0.0
        for kind in cfg.layer_pattern:
            ctx = min(shape.seq_len, cfg.local_window) if kind == "local" else shape.seq_len
            per_unit += 2.0 * cfg.kv_dim * ctx * 2  # QKᵀ + PV, 2 flops/MAC
        kv_read = per_unit * cfg.pattern_repeats
    return (2.0 * n_active + kv_read) * shape.global_batch
