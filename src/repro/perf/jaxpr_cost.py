"""Loop-aware FLOP/byte counting from the jaxpr IR.

XLA's CPU-backend ``compiled.cost_analysis()`` counts a ``while`` body
exactly once, so any scanned program (layers, microbatches, query chunks)
under-reports by the product of trip counts.  This module derives the
roofline inputs from the *jaxpr* instead, where ``scan`` carries its
``length`` explicitly and nesting recurses naturally:

  flops  — 2·M·N·K per dot_general/conv, |out| per elementwise op
  bytes  — Σ operand+result sizes per equation (an upper bound on HBM
           traffic, fusion-oblivious — the same philosophy as XLA's own
           "bytes accessed"; consistent across cells, so relative
           hillclimbing is sound)

Shapes in the jaxpr are global; dividing by chip count gives the per-chip
roofline under perfect balance, which is exactly the roofline model's
assumption.  Collective bytes still come from the compiled HLO (SPMD
collectives only exist post-partitioning).
"""

from __future__ import annotations

from functools import lru_cache

import jax
import numpy as np
from jax import core as jcore

__all__ = ["jaxpr_cost", "trace_cost"]

_ELEMENTWISE_FLOP1 = {
    "add", "add_any", "sub", "mul", "div", "max", "min", "neg", "abs", "floor", "ceil",
    "and", "or", "xor", "not", "select_n", "sign", "round", "clamp",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "eq", "ne", "lt", "le", "gt", "ge", "convert_element_type",
}
_ELEMENTWISE_FLOP10 = {"exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "pow",
                       "erf", "sin", "cos", "cbrt", "log1p", "expm1", "integer_pow"}


def _size(aval) -> float:
    try:
        return float(np.prod(aval.shape)) if aval.shape else 1.0
    except Exception:
        return 0.0


def _bytes(aval) -> float:
    try:
        return _size(aval) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    m = _size(eqn.outvars[0].aval)
    k = 1.0
    for d in lc:
        k *= lhs.shape[d]
    return 2.0 * m * k


def _eqn_cost(eqn) -> tuple[float, float]:
    """(flops, bytes) for one equation, recursing into sub-jaxprs."""
    prim = eqn.primitive.name

    if prim == "scan":
        f, b = _jaxpr_cost(eqn.params["jaxpr"].jaxpr)
        n = eqn.params["length"]
        return f * n, b * n
    if prim == "while":
        # our only while loops come from lax.scan; fori-style loops carry
        # no static count — treat body once (conservative) unless bounded.
        f, b = _jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
        return f, b
    if prim == "cond":
        costs = [_jaxpr_cost(br.jaxpr) for br in eqn.params["branches"]]
        return max(c[0] for c in costs), max(c[1] for c in costs)
    if prim in ("pjit", "jit", "closed_call", "core_call", "remat_call",
                "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr"):
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                sub = eqn.params[key]
                return _jaxpr_cost(getattr(sub, "jaxpr", sub))
        return 0.0, 0.0
    if prim == "remat2" or prim == "checkpoint":
        return _jaxpr_cost(eqn.params["jaxpr"])
    if prim == "shard_map":
        return _jaxpr_cost(eqn.params["jaxpr"])

    io_bytes = sum(_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
    io_bytes += sum(_bytes(v.aval) for v in eqn.outvars)

    if prim == "dot_general":
        return _dot_flops(eqn), io_bytes
    if prim in ("conv_general_dilated",):
        out = _size(eqn.outvars[0].aval)
        lhs = eqn.invars[1].aval  # kernel
        k = _size(lhs) / max(lhs.shape[-1], 1)
        return 2.0 * out * k, io_bytes
    out_sz = sum(_size(v.aval) for v in eqn.outvars)
    # Fused-roofline byte model: elementwise producers/consumers fuse into
    # the surrounding materialization points (dots, reshuffles, reductions,
    # scan boundaries), so only those count HBM traffic.  Elementwise and
    # broadcast ops contribute FLOPs but zero bytes.
    if prim in _ELEMENTWISE_FLOP10:
        return 10.0 * out_sz, 0.0
    if prim in _ELEMENTWISE_FLOP1:
        return out_sz, 0.0
    if prim in ("broadcast_in_dim", "reshape", "squeeze", "expand_dims",
                "copy", "iota", "stop_gradient", "transpose", "rev"):
        return 0.0, 0.0
    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "reduce_and", "reduce_or", "argmax", "argmin", "reduce_precision",
                "cumsum", "cumlogsumexp", "cummax", "cumprod", "logistic",
                "softmax", "logsumexp"):
        # reductions fuse with their producers: traffic charged where the
        # input was materialized (dot output, gather, …); count output only.
        in_sz = sum(_size(v.aval) for v in eqn.invars if hasattr(v, "aval"))
        out_bytes = sum(_bytes(v.aval) for v in eqn.outvars)
        return in_sz, out_bytes
    if prim in ("sort", "top_k"):
        in_sz = sum(_size(v.aval) for v in eqn.invars if hasattr(v, "aval"))
        return 10.0 * in_sz, io_bytes  # ~log factor
    # In-place update ops touch only the updated region, not the operand
    # (XLA aliases the buffer): read update + write region + indices.
    if prim == "dynamic_update_slice":
        upd = _bytes(eqn.invars[1].aval)
        return 0.0, 2.0 * upd
    if prim.startswith("scatter"):
        upd = _bytes(eqn.invars[-1].aval)
        idx = _bytes(eqn.invars[1].aval) if len(eqn.invars) > 2 else 0.0
        return 0.0, 2.0 * upd + idx
    # gathers read only the gathered rows: indices + 2×output.
    if prim in ("gather", "dynamic_slice", "take"):
        idx = sum(_bytes(v.aval) for v in eqn.invars[1:] if hasattr(v, "aval"))
        out = sum(_bytes(v.aval) for v in eqn.outvars)
        return 0.0, 2.0 * out + idx
    # remaining data movement (concat, pad, select into new buffers)
    return 0.0, io_bytes


def _jaxpr_cost(jaxpr) -> tuple[float, float]:
    f = b = 0.0
    for eqn in jaxpr.eqns:
        df, db = _eqn_cost(eqn)
        f += df
        b += db
    return f, b


def jaxpr_cost(closed_jaxpr) -> dict:
    f, b = _jaxpr_cost(closed_jaxpr.jaxpr)
    return {"flops": f, "bytes": b}


def trace_cost(fn, *args, **kwargs) -> dict:
    """Trace fn abstractly and count (no compile, no allocation)."""
    cj = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_cost(cj)
