"""PowerSGD low-rank gradient compression for the cross-pod axis.

Vogels et al. (2019) — the same power-iteration solver GEAR cites for its
SVDSolver (Algorithm 2) — applied to distributed training: per-pod partial
gradients are factored as ``G ≈ A Bᵀ`` (rank r, warm-started, with error
feedback), and only the factors cross the inter-pod links (``r·(n+m)``
elements instead of ``n·m``).  In-pod reduction stays exact: the train loop
wraps the step in ``shard_map`` manual only over ``pod``, leaving
``data``/``model`` to the SPMD partitioner (hierarchical reduction).

Matrices with fewer than ``min_size`` elements, and 1-D params, are
all-reduced exactly (compression overhead would dominate).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lowrank

__all__ = ["CompressorConfig", "init_error_feedback", "compressed_psum"]


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    rank: int = 8
    power_iters: int = 2
    min_size: int = 65536
    axis: str = "pod"

    def compressible(self, leaf: jnp.ndarray) -> bool:
        return leaf.ndim >= 2 and leaf.size >= self.min_size


def init_error_feedback(params: Any, cfg: CompressorConfig) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if cfg.compressible(p) else None,
        params)


def _as_matrix(g: jnp.ndarray) -> jnp.ndarray:
    """Collapse leading dims: [.., n, m] -> [n', m] with m the last dim."""
    return g.reshape(-1, g.shape[-1])


def compressed_psum(grads: Any, ef: Any, cfg: CompressorConfig, key: jax.Array):
    """All-reduce grads over ``cfg.axis`` with PowerSGD compression.

    MUST be called inside shard_map with ``cfg.axis`` a manual axis.
    Returns (mean grads, new error-feedback state, bytes metrics).
    """
    # jax.lax.axis_size is missing on older jax; psum(1) is the same number.
    if hasattr(jax.lax, "axis_size"):
        n_dev = jax.lax.axis_size(cfg.axis)
    else:
        n_dev = jax.lax.psum(1, cfg.axis)
    exact_bytes = jnp.zeros((), jnp.float32)
    comp_bytes = jnp.zeros((), jnp.float32)

    flat, treedef = jax.tree_util.tree_flatten(grads)
    ef_flat = jax.tree_util.tree_flatten(ef, is_leaf=lambda x: x is None)[0]
    out, new_ef = [], []
    for i, (g, e) in enumerate(zip(flat, ef_flat)):
        if not cfg.compressible(g):
            out.append(jax.lax.pmean(g, cfg.axis))
            new_ef.append(None)
            exact_bytes += g.size * 4
            continue
        gm = _as_matrix(g.astype(jnp.float32)) + _as_matrix(e)
        a, b = lowrank.power_iteration(gm, cfg.rank, cfg.power_iters,
                                       jax.random.fold_in(key, i),
                                       orthonormalizer="mgs")
        a = jax.lax.pmean(a, cfg.axis)
        b = jax.lax.pmean(b, cfg.axis)
        approx = lowrank.apply_lowrank(a, b)
        new_ef.append((gm - approx).reshape(g.shape))     # local error feedback
        out.append(approx.reshape(g.shape).astype(g.dtype))
        comp_bytes += (a.size + b.size) * 4
    metrics = {"exact_bytes": exact_bytes, "compressed_bytes": comp_bytes,
               "n_dev": jnp.asarray(n_dev, jnp.float32)}
    return jax.tree_util.tree_unflatten(treedef, out), \
        jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(ef, is_leaf=lambda x: x is None), new_ef), metrics
