"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lr_at"]


def lr_at(step, *, peak: float, total_steps: int, warmup: int = 100,
          kind: str = "cosine", stable_frac: float = 0.8,
          final_frac: float = 0.1) -> jnp.ndarray:
    s = jnp.asarray(step, jnp.float32)
    warm = peak * jnp.minimum(1.0, s / max(warmup, 1))
    if kind == "wsd":
        # warmup → stable plateau → short exponential-ish linear decay
        stable_end = warmup + stable_frac * (total_steps - warmup)
        decay_span = jnp.maximum(total_steps - stable_end, 1.0)
        frac = jnp.clip((s - stable_end) / decay_span, 0.0, 1.0)
        post = peak * (1.0 - (1.0 - final_frac) * frac)
        return jnp.where(s < warmup, warm, jnp.where(s < stable_end, peak, post))
    prog = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
    cos = final_frac * peak + (1 - final_frac) * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
