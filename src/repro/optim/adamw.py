"""Minimal functional AdamW (no optax dependency) with global-norm clipping."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["mu", "nu", "count"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class AdamWState:
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return AdamWState(mu=zeros(params), nu=zeros(params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any,
                 lr: jnp.ndarray):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = state.count + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, n):
        step = (m / b1c) / (jnp.sqrt(n / b2c) + cfg.eps)
        return (p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), {"grad_norm": gnorm}
