"""Serving observability: metrics registry, request tracing, fidelity probes.

Enable with ``EngineConfig(obs=ObsConfig(...))`` (or ``obs=True`` for
defaults).  The engine owns one :class:`Observability` per instance; the
scheduler discovers it via ``engine.obs`` and drives the request
lifecycle, the engine feeds prefill annotations and fidelity probes, the
fault injector reports firings.  Everything here is no-op-safe: a
missing/disabled subsystem never raises into the serving path.

See ``docs/observability.md`` for the metric catalog, span schema, and
export formats.
"""

from __future__ import annotations

import dataclasses
import json
import time

from .catalog import METRICS, MetricSpec, build_registry
from .registry import (CardinalityError, Counter, Gauge, Histogram, Registry,
                       parse_prometheus)
from .tracing import RequestTrace, Span, Tracer, profiler_span

__all__ = [
    "ObsConfig", "Observability",
    "Registry", "Counter", "Gauge", "Histogram", "CardinalityError",
    "parse_prometheus", "Tracer", "Span", "RequestTrace", "profiler_span",
    "MetricSpec", "METRICS", "build_registry",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Telemetry knobs (the ``EngineConfig.obs`` field; ``obs=True``
    coerces to defaults).

    ``metrics``/``tracing`` toggle the registry sync and per-request
    spans.  ``fidelity_every_n`` samples a compression-fidelity probe
    each time the running closed-chunk count crosses a multiple of N
    (0 = off); ``fidelity_budget_frac`` caps measured probe wall time at
    that fraction of elapsed real time.  ``profiler`` wraps prefill and
    decode jit calls in ``jax.profiler`` trace annotations.
    """

    metrics: bool = True
    tracing: bool = True
    fidelity_every_n: int = 0
    fidelity_budget_frac: float = 0.05
    profiler: bool = False

    def __post_init__(self):
        if self.fidelity_every_n < 0:
            raise ValueError("fidelity_every_n must be >= 0 (0 disables)")
        if not 0.0 < self.fidelity_budget_frac <= 1.0:
            raise ValueError("fidelity_budget_frac must be in (0, 1]")


class Observability:
    """Per-engine telemetry hub: registry + tracer + (optional) fidelity
    probe, with convenience emitters the serving layers call.  All
    emitters are cheap and exception-free by construction (label sets are
    closed; see :mod:`repro.obs.catalog`)."""

    def __init__(self, cfg: ObsConfig, clock=None):
        self.cfg = cfg
        self.clock = time.monotonic if clock is None else clock
        self.registry = build_registry(clock=self.clock)
        self.tracer = Tracer(clock=self.clock, enabled=cfg.tracing)
        self.fidelity = None  # attached by the engine when probes are on
        self._m = bool(cfg.metrics)
        self._synced: dict = {}

    # -- scheduler lifecycle ----------------------------------------------
    def on_submit(self, rid: int) -> None:
        if self._m:
            self.registry.get("serving_requests_submitted_total").inc()
        self.tracer.start(rid)
        self.tracer.begin(rid, "queued")

    def on_shed(self, rid: int) -> None:
        if self._m:
            self.registry.get("serving_requests_shed_total").inc()
        self.tracer.start(rid)
        self.tracer.finish(rid, "rejected")

    def result(self, status) -> None:
        if self._m:
            self.registry.get("serving_results_total").inc(status=str(status))

    def retry(self, kind: str) -> None:
        if self._m:
            self.registry.get("serving_retries_total").inc(kind=kind)

    def quarantine(self) -> None:
        if self._m:
            self.registry.get("serving_quarantine_total").inc()

    def fault_fired(self, site: str, visit: int) -> None:
        if self._m:
            self.registry.get("serving_faults_injected_total").inc(site=site)
        self.tracer.event_bound("fault", site=site, visit=visit)

    def decode_step(self, seconds: float, n_active: int) -> None:
        if self._m:
            self.registry.get("serving_decode_steps_total").inc()
            self.registry.get("serving_tokens_generated_total").inc(n_active)
            self.registry.get("serving_decode_step_seconds").observe(seconds)

    def queue_depth(self, n: int) -> None:
        if self._m:
            self.registry.get("serving_queue_depth").set(n)

    def observe_prefill(self, seconds: float) -> None:
        if self._m:
            self.registry.get("serving_prefill_seconds").observe(seconds)

    def observe_queue_wait(self, seconds: float) -> None:
        if self._m:
            self.registry.get("serving_queue_wait_seconds").observe(seconds)

    def observe_bucket(self, tokens: int) -> None:
        if self._m:
            self.registry.get("serving_prefill_bucket_tokens").observe(tokens)

    # -- lifetime-counter sync --------------------------------------------
    def sync_counter(self, name: str, cumulative: float, **labels) -> None:
        """Mirror an externally-owned cumulative counter (pool/trie stats
        dicts, which reset when their owner is rebuilt) into a registry
        counter by delta; a value below the last-seen one means the
        source was reset, so the whole new value is fresh growth."""
        key = (name, tuple(sorted(labels.items())))
        seen = self._synced.get(key, 0.0)
        if cumulative < seen:
            seen = 0.0
        delta = cumulative - seen
        if delta > 0:
            self.registry.get(name).inc(delta, **labels)
        self._synced[key] = cumulative

    def sync_pool(self, snap) -> None:
        """snap: a PoolSnapshot (serving/pagedpool.py)."""
        if not self._m:
            return
        for field, metric in (("admits", "pool_admits_total"),
                              ("rejects", "pool_rejects_total"),
                              ("shared_pages", "pool_shared_pages_total"),
                              ("fresh_pages", "pool_fresh_pages_total"),
                              ("freed_pages", "pool_freed_pages_total")):
            self.sync_counter(metric, snap[field])
        self.registry.get("pool_free_pages").set(snap["free_pages"])
        self.registry.get("pool_used_pages").set(snap["used_pages"])

    def sync_prefix(self, snap) -> None:
        """snap: a PrefixSnapshot (repro/prefixcache)."""
        if not self._m:
            return
        for field, metric in (
                ("lookup_chunks", "prefix_lookup_chunks_total"),
                ("hit_chunks", "prefix_hit_chunks_total"),
                ("inserts", "prefix_inserts_total"),
                ("evictions", "prefix_evictions_total"),
                ("expiries", "prefix_expiries_total"),
                ("version_evictions", "prefix_version_evictions_total"),
                ("prefill_toks_saved", "prefix_toks_saved_total"),
                ("validate_failures", "prefix_validate_failures_total")):
            self.sync_counter(metric, snap[field])
        self.registry.get("prefix_nodes").set(snap["nodes"])
        self.registry.get("prefix_bytes").set(snap["bytes"])

    # -- exports -----------------------------------------------------------
    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def to_prometheus(self) -> str:
        return self.registry.to_prometheus()

    def to_json(self, indent: int | None = None) -> str:
        return self.registry.to_json(indent=indent)

    def write_metrics_json(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    def write_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.tracer.to_chrome(), f, indent=2, sort_keys=True)
