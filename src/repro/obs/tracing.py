"""Per-request trace spans for the serving lifecycle.

A :class:`Tracer` records one :class:`RequestTrace` per rid through
``submit → admit/shed → prefill [prefix-hit, bucket, pages reserved] →
splice → decode → retire``, plus instant events for retries, fault
injections, and numeric-quarantine hits.  The scheduler drives the
lifecycle; the engine — which never sees rids — contributes via a
*bound* rid (:meth:`Tracer.bind` around ``view.prefill_slot``), through
which it annotates the open prefill span and wraps the splice.

Design rules:

* **Never crash serving.** Every method no-ops on unknown rids and
  unbalanced span calls; tracing is an observer, not a participant.
* **Injectable clock.** Timestamps come from the same clock the
  scheduler uses (``FakeClock`` in tests), so traces are deterministic
  under the chaos harness.
* **Single-threaded scheduler assumption.** One bound rid at a time is
  enough because ``run_continuous`` is a single-threaded loop; the
  registry (not the tracer) is the thread-safe layer.

Export is Chrome ``trace_event`` JSON (:meth:`Tracer.to_chrome`, load in
``chrome://tracing`` / Perfetto): each request is a ``tid``, spans are
complete (``"ph": "X"``) events, instants are ``"ph": "i"``.  For
wall-clock profiling of the jitted calls themselves,
:func:`profiler_span` optionally opens a ``jax.profiler``
``TraceAnnotation`` so prefill/decode show up named in XLA profiles.
"""

from __future__ import annotations

import contextlib
import json
import time

__all__ = ["Span", "RequestTrace", "Tracer", "profiler_span", "TRACE_SCHEMA"]

TRACE_SCHEMA = "gear-repro/trace/v1"


class Span:
    """One named interval inside a request trace."""

    __slots__ = ("name", "t0", "t1", "args")

    def __init__(self, name: str, t0: float, args: dict | None = None):
        self.name = name
        self.t0 = float(t0)
        self.t1: float | None = None
        self.args: dict = dict(args or {})

    def as_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "args": dict(self.args)}


class RequestTrace:
    """Everything recorded about one rid: spans, instant events, terminal
    status.  ``events`` entries are ``(name, t, args)`` tuples."""

    __slots__ = ("rid", "t_submit", "t_end", "status", "spans", "events",
                 "decode_steps", "attempts", "_open")

    def __init__(self, rid: int, t_submit: float):
        self.rid = rid
        self.t_submit = float(t_submit)
        self.t_end: float | None = None
        self.status = ""            # terminal RequestStatus value once retired
        self.spans: list[Span] = []
        self.events: list[tuple[str, float, dict]] = []
        self.decode_steps = 0
        self.attempts = 0
        self._open: list[Span] = []  # innermost-last stack of open spans

    @property
    def done(self) -> bool:
        return self.t_end is not None

    def as_dict(self) -> dict:
        return {"rid": self.rid, "status": self.status,
                "t_submit": self.t_submit, "t_end": self.t_end,
                "decode_steps": self.decode_steps, "attempts": self.attempts,
                "spans": [s.as_dict() for s in self.spans],
                "events": [{"name": n, "t": t, "args": a}
                           for n, t, a in self.events]}


class Tracer:
    """Collects request traces; see module docstring for the contract."""

    def __init__(self, clock=None, enabled: bool = True,
                 max_completed: int = 4096):
        self.clock = time.monotonic if clock is None else clock
        self.enabled = bool(enabled)
        self.max_completed = int(max_completed)
        self.active: dict[int, RequestTrace] = {}
        self.completed: list[RequestTrace] = []
        self._bound: int | None = None

    # -- lifecycle ---------------------------------------------------------
    def start(self, rid: int) -> None:
        if not self.enabled:
            return
        stale = self.active.pop(rid, None)
        if stale is not None:       # resubmitted while active: a scheduler
            self._finish_trace(stale, "abandoned")  # bug, keep the evidence
        self.active[rid] = RequestTrace(rid, self.clock())

    def finish(self, rid: int, status: str) -> None:
        tr = self.active.pop(rid, None)
        if tr is not None:
            self._finish_trace(tr, str(status))

    def _finish_trace(self, tr: RequestTrace, status: str) -> None:
        now = self.clock()
        while tr._open:             # auto-close dangling spans
            sp = tr._open.pop()
            sp.t1 = now
            tr.spans.append(sp)
        tr.status = status
        tr.t_end = now
        if len(self.completed) < self.max_completed:
            self.completed.append(tr)

    def reset(self) -> None:
        """Drop all traces (benches call this between warmup and measured
        drives so coverage checks see exactly one trace per rid)."""
        self.active.clear()
        self.completed.clear()
        self._bound = None

    # -- spans and events --------------------------------------------------
    def begin(self, rid: int, name: str, **args) -> None:
        tr = self.active.get(rid)
        if tr is not None:
            tr._open.append(Span(name, self.clock(), args))

    def end(self, rid: int) -> None:
        tr = self.active.get(rid)
        if tr is not None and tr._open:
            sp = tr._open.pop()
            sp.t1 = self.clock()
            tr.spans.append(sp)

    @contextlib.contextmanager
    def span(self, rid: int, name: str, **args):
        self.begin(rid, name, **args)
        try:
            yield
        finally:
            self.end(rid)

    def add_span(self, rid: int, name: str, dur: float, **args) -> None:
        """Record an already-elapsed interval ending now (used for the
        aggregate decode span, whose per-step timing lives in the
        histogram)."""
        tr = self.active.get(rid)
        if tr is not None:
            t1 = self.clock()
            sp = Span(name, t1 - float(dur), args)
            sp.t1 = t1
            tr.spans.append(sp)

    def event(self, rid: int, name: str, **args) -> None:
        tr = self.active.get(rid)
        if tr is not None:
            tr.events.append((name, self.clock(), dict(args)))

    def step(self, rid: int, n: int = 1) -> None:
        tr = self.active.get(rid)
        if tr is not None:
            tr.decode_steps += int(n)

    def attempt(self, rid: int) -> None:
        tr = self.active.get(rid)
        if tr is not None:
            tr.attempts += 1

    # -- bound rid (engine-side correlation) -------------------------------
    def bind(self, rid: int) -> None:
        self._bound = rid

    def unbind(self) -> None:
        self._bound = None

    def annotate(self, **args) -> None:
        """Merge args into the innermost open span of the bound trace
        (falling back to the trace's last closed span); no-op unbound."""
        tr = self.active.get(self._bound) if self._bound is not None else None
        if tr is None:
            return
        if tr._open:
            tr._open[-1].args.update(args)
        elif tr.spans:
            tr.spans[-1].args.update(args)

    def span_bound(self, name: str, **args):
        if self._bound is None:
            return contextlib.nullcontext()
        return self.span(self._bound, name, **args)

    def event_bound(self, name: str, **args) -> None:
        if self._bound is not None:
            self.event(self._bound, name, **args)

    # -- queries -----------------------------------------------------------
    def coverage(self, rids) -> dict:
        """Report trace coverage over ``rids``: per-rid completed-trace
        counts plus missing/duplicate/unfinished lists.  The chaos tests
        and ``bench_throughput --obs`` assert ``complete`` is True."""
        want = list(rids)
        counts: dict[int, int] = {}
        statuses: dict[int, str] = {}
        for tr in self.completed:
            counts[tr.rid] = counts.get(tr.rid, 0) + 1
            statuses[tr.rid] = tr.status
        missing = [r for r in want if counts.get(r, 0) == 0]
        duplicates = [r for r in want if counts.get(r, 0) > 1]
        unfinished = sorted(self.active)
        return {"complete": not missing and not duplicates and not unfinished,
                "missing": missing, "duplicates": duplicates,
                "unfinished": unfinished, "statuses": statuses}

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON object (``traceEvents`` key plus a
        schema tag; extra keys are ignored by viewers)."""
        ev: list[dict] = []
        t0 = min((tr.t_submit for tr in self.completed), default=0.0)

        def us(t: float) -> float:
            return (t - t0) * 1e6

        for tr in self.completed:
            end = tr.t_end if tr.t_end is not None else tr.t_submit
            ev.append({"name": "request", "cat": "request", "ph": "X",
                       "pid": 0, "tid": tr.rid, "ts": us(tr.t_submit),
                       "dur": us(end) - us(tr.t_submit),
                       "args": {"rid": tr.rid, "status": tr.status,
                                "decode_steps": tr.decode_steps,
                                "attempts": tr.attempts}})
            for sp in tr.spans:
                t1 = sp.t1 if sp.t1 is not None else end
                ev.append({"name": sp.name, "cat": "span", "ph": "X",
                           "pid": 0, "tid": tr.rid, "ts": us(sp.t0),
                           "dur": us(t1) - us(sp.t0), "args": dict(sp.args)})
            for name, t, args in tr.events:
                ev.append({"name": name, "cat": "event", "ph": "i", "s": "t",
                           "pid": 0, "tid": tr.rid, "ts": us(t),
                           "args": dict(args)})
        ev.sort(key=lambda e: (e["tid"], e["ts"], e["ph"]))
        return {"schema": TRACE_SCHEMA, "traceEvents": ev}

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_chrome(), indent=indent, sort_keys=True)


def profiler_span(name: str, enabled: bool):
    """Context manager: a ``jax.profiler.TraceAnnotation`` when enabled
    (so prefill/decode jit calls are named in XLA profiles), else a
    no-op.  Import is lazy and failure-tolerant — tracing must work in
    environments where the profiler is unavailable."""
    if not enabled:
        return contextlib.nullcontext()
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()
