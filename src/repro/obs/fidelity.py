"""Online compression-fidelity probes.

GEAR's headline claim is *near-lossless* compression; the parity tests
prove it offline, these probes measure it **in production**, per layer,
on live traffic.  The engine calls :meth:`FidelityProbe.maybe_probe`
right after each prefill's numeric guard — on the read-only batch-1
cache tree, *before* the donating splice — so probing can never perturb
serving state (the probe-parity sweep in ``tests/test_cache.py`` pins
caches and logits bit-identical probe-on vs probe-off).

Mechanics per sampled request:

1. **Shadow reference.** Streaming prefill discards the raw K/V, so the
   probe reruns the prompt through a jitted fp16 monolithic prefill
   (``ref_prefill``, built by the engine from the same model/params with
   the :data:`~repro.core.policy.FP16` policy at the same capacity).
   FP16 cache leaves at a GEAR position are exactly the uncompressed
   K/V, position-aligned with the compressed tree.
2. **Reconstruction compare.**  One jitted program vmaps
   :func:`repro.core.cache.dense_kv` over the repeat axis of every GEAR
   position and reduces masked-Frobenius statistics over the *closed*
   region (``tok < (length // n_b) * n_b`` — the buffer tail is stored
   fp16 and trivially exact).  Masking with the traced length means one
   program total, not one per prompt length.  Per layer it records
   relative Frobenius error of K̂/V̂ (:func:`repro.core.metrics.rel_frobenius`
   semantics), low-rank residual share, and sparse-outlier mass; plus
   the max-abs last-position logits drift vs the shadow.
3. **Budget throttle.** Probes cost a full fp16 prefill, so a measured
   wall-clock budget (``budget_frac`` of elapsed real time since the
   probe was created) skips sampling when probing would exceed it —
   counted in ``fidelity_probe_skipped_total``, never blocking serving.
   The throttle uses ``time.perf_counter`` (not the injectable serving
   clock) because it compares *real* costs; the first eligible probe
   always runs.

Sampling is "every Nth closed chunk": a running count of closed chunks
crossing a multiple of ``every_n`` triggers a probe, so heavier prompts
are sampled proportionally more.  Failures inside a probe increment
``fidelity_probe_errors_total`` and are swallowed — telemetry must never
take down serving.
"""

from __future__ import annotations

import collections
import time

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core.metrics import masked_rel_frobenius, masked_share

__all__ = ["FidelityProbe"]


class FidelityProbe:
    """See module docstring.

    Parameters
    ----------
    ref_prefill: callable(batch1_dict) -> (logits, caches)
        Jitted fp16 monolithic prefill of the engine's model/params.
    cache_cfgs: per-pattern-position batch-1 ``CacheConfig`` (``None``
        for positions without one, e.g. rwkv) — only ``kind == "gear"``
        positions are probed.
    registry: the obs :class:`~repro.obs.registry.Registry`.
    every_n: sample a probe each time the running closed-chunk count
        crosses a multiple of this (0 disables).
    budget_frac: measured-overhead ceiling as a fraction of real
        elapsed time.
    """

    def __init__(self, ref_prefill, cache_cfgs, policy, registry,
                 every_n: int, budget_frac: float = 0.05,
                 max_reports: int = 256):
        self._ref_prefill = ref_prefill
        self._ccfgs = list(cache_cfgs)
        self._pol = policy
        self._reg = registry
        self.every_n = int(every_n)
        self.budget_frac = float(budget_frac)
        self._gear_pos = [i for i, c in enumerate(self._ccfgs)
                          if c is not None and c.kind == "gear"]
        self._n_unit = len(self._ccfgs)
        self._chunks_seen = 0
        self._spent_s = 0.0
        self._born = time.perf_counter()
        self._fn = None  # jitted compare, built lazily on first probe
        self.reports: collections.deque = collections.deque(maxlen=max_reports)

    # -- sampling ----------------------------------------------------------
    def _due(self, n_closed: int) -> bool:
        if self.every_n <= 0 or n_closed <= 0 or not self._gear_pos:
            return False
        before = self._chunks_seen // self.every_n
        self._chunks_seen += n_closed
        return self._chunks_seen // self.every_n > before

    def _within_budget(self) -> bool:
        if self._spent_s == 0.0:
            return True  # first probe always runs
        elapsed = time.perf_counter() - self._born
        return self._spent_s <= self.budget_frac * max(elapsed, 1e-9)

    # -- the probe ---------------------------------------------------------
    def maybe_probe(self, batch1: dict, logits, one) -> dict | None:
        """Sample-and-measure hook; returns the report dict when a probe
        ran, else None.  Read-only on all arguments."""
        try:
            n_tok = int(jnp.asarray(batch1["tokens"]).shape[-1])
            n_closed = n_tok // self._pol.buffer_size
            if not self._due(n_closed):
                return None
            if not self._within_budget():
                self._reg.get("fidelity_probe_skipped_total").inc()
                return None
            t0 = time.perf_counter()
            report = self._probe(batch1, logits, one, n_tok, n_closed)
            dt = time.perf_counter() - t0
            self._spent_s += dt
            self._reg.get("fidelity_probe_seconds").observe(dt)
            self._reg.get("fidelity_probes_total").inc()
            self.reports.append(report)
            return report
        except Exception:
            try:
                self._reg.get("fidelity_probe_errors_total").inc()
            except Exception:
                pass
            return None

    def _probe(self, batch1, logits, one, n_tok, n_closed) -> dict:
        ref_logits, ref_caches = self._ref_prefill(batch1)
        if self._fn is None:
            self._fn = self._build_fn()
        stats = self._fn(one, ref_caches)
        drift = float(jnp.max(jnp.abs(
            jnp.asarray(logits, jnp.float32).reshape(-1)
            - jnp.asarray(ref_logits, jnp.float32).reshape(-1))))
        self._reg.get("fidelity_logits_drift").observe(drift)
        layers = []
        for i in self._gear_pos:
            per_rep = {k: jax.device_get(v) for k, v in stats[i].items()}
            n_rep = len(next(iter(per_rep.values())))
            for r in range(n_rep):
                layer = r * self._n_unit + i
                row = {"layer": layer}
                for key, vals in per_rep.items():
                    row[key] = float(vals[r])
                layers.append(row)
                lab = str(layer)
                self._reg.get("fidelity_sampled_chunks_total").inc(
                    n_closed, layer=lab)
                for field in ("k", "v"):
                    self._reg.get("fidelity_rel_err").observe(
                        row[f"{field}_rel_err"], field=field, layer=lab)
                    if f"{field}_lowrank_share" in row:
                        self._reg.get("fidelity_lowrank_share").observe(
                            row[f"{field}_lowrank_share"], field=field,
                            layer=lab)
                    if f"{field}_outlier_mass" in row:
                        self._reg.get("fidelity_outlier_mass").observe(
                            row[f"{field}_outlier_mass"], field=field,
                            layer=lab)
        layers.sort(key=lambda r: r["layer"])
        return {"prompt_tokens": n_tok, "closed_chunks": n_closed,
                "logits_drift": drift, "layers": layers}

    def _build_fn(self):
        """One jitted compare program for all prompt lengths: closed-region
        masks come from the (traced) cache lengths."""
        ccfgs, pol, gear_pos = self._ccfgs, self._pol, self._gear_pos

        def per_rep(ccfg, lyr, ref):
            nb = ccfg.chunk
            n_comp = (lyr.length // nb) * nb                      # [1]
            tok = jnp.arange(ccfg.capacity)
            mask = (tok[None, :] < n_comp[:, None])[:, None, :, None]
            k_hat, v_hat = cache_lib.dense_kv(ccfg, lyr)
            k_ref = ref.k.astype(jnp.float32)
            v_ref = ref.v.astype(jnp.float32)
            out = {"k_rel_err": masked_rel_frobenius(k_hat, k_ref, mask),
                   "v_rel_err": masked_rel_frobenius(v_hat, v_ref, mask)}
            if pol.use_lowrank:
                out["k_lowrank_share"] = masked_share(
                    cache_lib._lowrank_dense(ccfg, lyr.k_a, lyr.k_b), k_hat, mask)
                out["v_lowrank_share"] = masked_share(
                    cache_lib._lowrank_dense(ccfg, lyr.v_a, lyr.v_b), v_hat, mask)
            if pol.use_sparse:
                out["k_outlier_mass"] = masked_share(
                    cache_lib._sparse_dense(ccfg, lyr.k_sp_val, lyr.k_sp_idx, "k"),
                    k_hat, mask)
                out["v_outlier_mass"] = masked_share(
                    cache_lib._sparse_dense(ccfg, lyr.v_sp_val, lyr.v_sp_idx, "v"),
                    v_hat, mask)
            return out

        @jax.jit
        def fn(one, ref_caches):
            return {i: jax.vmap(lambda lyr, ref, c=ccfgs[i]: per_rep(c, lyr, ref))(
                        one[i], ref_caches[i])
                    for i in gear_pos}

        return fn
