"""Dependency-free metrics registry: counters, gauges, histograms.

The serving stack's runtime telemetry substrate (ISSUE 10).  Three metric
kinds with Prometheus-compatible semantics, each supporting a fixed label
schema with a **bounded** number of label sets (unbounded label
cardinality is the classic way a metrics layer eats the heap — exceeding
the bound raises :class:`CardinalityError` loudly instead of growing
silently, and every label set the serving stack emits is drawn from an
enum or a layer index, so the bound is a bug detector, not a limiter):

* :class:`Counter` — monotonically increasing float (``inc``);
* :class:`Gauge` — settable level (``set`` / ``inc`` / ``dec``);
* :class:`Histogram` — fixed upper-bound buckets + sum + count
  (``observe``), exposed cumulatively the way Prometheus expects.

A :class:`Registry` owns the metrics, takes an **injectable clock** (the
same ``FakeClock`` the scheduler/trie/faults share in tests, so snapshots
are deterministic), is thread-safe (one lock per registry — metric
updates are O(dict lookup), contention is irrelevant next to a jitted
step), and exports three ways:

* :meth:`Registry.snapshot` — plain-dict, deterministically ordered
  (sorted metric names, sorted label sets);
* :meth:`Registry.to_json` — the snapshot as JSON
  (``gear-repro/metrics/v1`` schema, consumed by
  ``launch/serve.py --metrics-json`` and ``scripts/check_obs_export.py``);
* :meth:`Registry.to_prometheus` — text exposition format;
  :func:`parse_prometheus` round-trips it back into samples (the CI obs
  smoke asserts exporter output parses to the same values).

Nothing here imports jax/numpy — the registry is usable from any layer,
including host-only allocator code.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Iterable

__all__ = ["CardinalityError", "Counter", "Gauge", "Histogram", "Registry",
           "parse_prometheus", "METRICS_SCHEMA"]

METRICS_SCHEMA = "gear-repro/metrics/v1"

# default histogram buckets (seconds) — roughly prometheus defaults
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_NAME_OK = set("abcdefghijklmnopqrstuvwxyz"
               "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


class CardinalityError(RuntimeError):
    """A metric exceeded its ``max_label_sets`` bound.

    Label values in the serving stack come from closed sets (status
    enums, fault sites, layer indices), so hitting this means a caller is
    labelling with unbounded data (rids, prompts) — a bug worth failing
    loudly on rather than leaking memory over.
    """


def _check_name(name: str, what: str) -> str:
    if not name or not set(name) <= _NAME_OK or name[0].isdigit():
        raise ValueError(f"invalid {what} {name!r}")
    return name


class _Metric:
    """Shared label-set plumbing for all three kinds."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Iterable[str] = (),
                 max_label_sets: int = 64):
        self.name = _check_name(name, "metric name")
        self.help = str(help)
        self.label_names = tuple(_check_name(l, "label name") for l in labels)
        if len(set(self.label_names)) != len(self.label_names):
            raise ValueError(f"{name}: duplicate label names {self.label_names}")
        self.max_label_sets = int(max_label_sets)
        if self.max_label_sets < 1:
            raise ValueError(f"{name}: max_label_sets must be >= 1")
        self._series: dict[tuple[str, ...], Any] = {}
        self._lock = threading.Lock()

    def _key(self, labels: dict) -> tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[l]) for l in self.label_names)

    def _slot(self, labels: dict):
        key = self._key(labels)
        series = self._series.get(key)
        if series is None:
            if len(self._series) >= self.max_label_sets:
                raise CardinalityError(
                    f"{self.name}: {len(self._series)} label sets at the "
                    f"max_label_sets={self.max_label_sets} bound; refusing "
                    f"new set {dict(zip(self.label_names, key))}")
            series = self._series[key] = self._fresh()
        return key, series

    def _fresh(self):
        raise NotImplementedError

    def spec(self) -> dict:
        return {"name": self.name, "type": self.kind, "help": self.help,
                "labels": list(self.label_names)}

    def same_spec(self, other: "_Metric") -> bool:
        return (self.kind == other.kind and self.help == other.help
                and self.label_names == other.label_names)


class Counter(_Metric):
    kind = "counter"

    def _fresh(self) -> list:
        return [0.0]

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up (inc {amount})")
        with self._lock:
            _, series = self._slot(labels)
            series[0] += float(amount)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(self._key(labels), [0.0])[0])

    def series(self) -> list[dict]:
        with self._lock:
            return [{"labels": dict(zip(self.label_names, key)),
                     "value": series[0]}
                    for key, series in sorted(self._series.items())]


class Gauge(_Metric):
    kind = "gauge"

    def _fresh(self) -> list:
        return [0.0]

    def set(self, value: float, **labels) -> None:
        with self._lock:
            _, series = self._slot(labels)
            series[0] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        with self._lock:
            _, series = self._slot(labels)
            series[0] += float(amount)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(self._key(labels), [0.0])[0])

    series = Counter.series


class Histogram(_Metric):
    """Fixed-bucket histogram: ``observe(v)`` lands in the first bucket
    whose upper bound satisfies ``v <= le`` (Prometheus edge semantics);
    values above every bound land in the implicit ``+Inf`` bucket.
    Internally counts are per-bucket; exposition is cumulative."""

    kind = "histogram"

    def __init__(self, name: str, help: str, labels: Iterable[str] = (),
                 buckets: Iterable[float] = DEFAULT_BUCKETS,
                 max_label_sets: int = 64):
        super().__init__(name, help, labels, max_label_sets)
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"{name}: buckets must be sorted and unique, got {bs}")
        self.buckets = bs

    def _fresh(self) -> dict:
        return {"counts": [0] * (len(self.buckets) + 1), "sum": 0.0, "count": 0}

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        with self._lock:
            _, series = self._slot(labels)
            idx = len(self.buckets)
            for i, le in enumerate(self.buckets):
                if v <= le:
                    idx = i
                    break
            series["counts"][idx] += 1
            series["sum"] += v
            series["count"] += 1

    def spec(self) -> dict:
        return {**super().spec(), "buckets": list(self.buckets)}

    def same_spec(self, other: "_Metric") -> bool:
        return (super().same_spec(other)
                and self.buckets == getattr(other, "buckets", None))

    def series(self) -> list[dict]:
        with self._lock:
            out = []
            for key, series in sorted(self._series.items()):
                cum, cums = 0, []
                for c in series["counts"]:
                    cum += c
                    cums.append(cum)
                out.append({"labels": dict(zip(self.label_names, key)),
                            "sum": series["sum"], "count": series["count"],
                            "buckets": [
                                {"le": le, "count": cums[i]}
                                for i, le in enumerate(self.buckets)
                            ] + [{"le": "+Inf", "count": cums[-1]}]})
            return out


class Registry:
    """A named collection of metrics with deterministic export.

    ``clock`` is any zero-arg monotonic-seconds callable (tests inject the
    shared ``FakeClock``); it stamps snapshots only — metric values never
    depend on it, so two registries driven identically produce identical
    snapshots regardless of wall time.
    """

    def __init__(self, clock=None):
        self.clock = time.monotonic if clock is None else clock
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _register(self, metric: _Metric) -> _Metric:
        with self._lock:
            have = self._metrics.get(metric.name)
            if have is not None:
                if not have.same_spec(metric):
                    raise ValueError(
                        f"metric {metric.name!r} re-registered with a "
                        "different spec")
                return have
            self._metrics[metric.name] = metric
            return metric

    def counter(self, name: str, help: str = "", labels: Iterable[str] = (),
                max_label_sets: int = 64) -> Counter:
        return self._register(Counter(name, help, labels, max_label_sets))

    def gauge(self, name: str, help: str = "", labels: Iterable[str] = (),
              max_label_sets: int = 64) -> Gauge:
        return self._register(Gauge(name, help, labels, max_label_sets))

    def histogram(self, name: str, help: str = "", labels: Iterable[str] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS,
                  max_label_sets: int = 64) -> Histogram:
        return self._register(Histogram(name, help, labels, buckets,
                                        max_label_sets))

    def get(self, name: str) -> _Metric:
        try:
            return self._metrics[name]
        except KeyError:
            raise KeyError(f"metric {name!r} is not registered") from None

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    # -- exports -----------------------------------------------------------
    def snapshot(self) -> dict:
        """Deterministic plain-dict dump of every metric and series."""
        return {
            "schema": METRICS_SCHEMA,
            "time": float(self.clock()),
            "metrics": [{**m.spec(), "series": m.series()}
                        for _, m in sorted(self._metrics.items())],
        }

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (round-trips via
        :func:`parse_prometheus`)."""
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {_esc_help(m.help)}")
            lines.append(f"# TYPE {name} {m.kind}")
            for s in m.series():
                base = s["labels"]
                if m.kind == "histogram":
                    for b in s["buckets"]:
                        le = b["le"] if isinstance(b["le"], str) else _fmt(b["le"])
                        lines.append(f"{name}_bucket"
                                     f"{_labelstr({**base, 'le': le})} "
                                     f"{_fmt(b['count'])}")
                    lines.append(f"{name}_sum{_labelstr(base)} {_fmt(s['sum'])}")
                    lines.append(f"{name}_count{_labelstr(base)} "
                                 f"{_fmt(s['count'])}")
                else:
                    lines.append(f"{name}{_labelstr(base)} {_fmt(s['value'])}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _esc_help(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _esc_label(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labelstr(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def parse_prometheus(text: str) -> dict[tuple[str, tuple[tuple[str, str], ...]], float]:
    """Parse text exposition back into ``{(name, sorted label items): value}``.

    Supports exactly the subset :meth:`Registry.to_prometheus` emits
    (which is the standard sample-line grammar without timestamps) — the
    round-trip the CI obs smoke asserts.  Raises ``ValueError`` on any
    malformed sample line.
    """
    out: dict[tuple[str, tuple[tuple[str, str], ...]], float] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        name, labels, rest = _parse_sample(line, lineno)
        try:
            value = float(rest)
        except ValueError:
            raise ValueError(f"line {lineno}: bad sample value {rest!r}") from None
        out[(name, tuple(sorted(labels.items())))] = value
    return out


def _parse_sample(line: str, lineno: int):
    brace = line.find("{")
    if brace < 0:
        parts = line.split()
        if len(parts) != 2:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        return _check_name(parts[0], "metric name"), {}, parts[1]
    name = _check_name(line[:brace], "metric name")
    end = line.rfind("}")
    if end < brace:
        raise ValueError(f"line {lineno}: unterminated label set {line!r}")
    labels: dict[str, str] = {}
    body, i = line[brace + 1:end], 0
    while i < len(body):
        eq = body.find("=", i)
        if eq < 0 or body[eq + 1:eq + 2] != '"':
            raise ValueError(f"line {lineno}: malformed labels {body!r}")
        key = _check_name(body[i:eq].strip(), "label name")
        j, val = eq + 2, []
        while j < len(body):
            ch = body[j]
            if ch == "\\" and j + 1 < len(body):
                val.append({"n": "\n", "\\": "\\", '"': '"'}.get(
                    body[j + 1], body[j + 1]))
                j += 2
                continue
            if ch == '"':
                break
            val.append(ch)
            j += 1
        else:
            raise ValueError(f"line {lineno}: unterminated label value")
        labels[key] = "".join(val)
        i = j + 1
        if i < len(body) and body[i] == ",":
            i += 1
    return name, labels, line[end + 1:].strip()
