"""Declared metric catalog for the serving stack.

Every metric the serving/obs layers emit is declared here as a literal
:class:`MetricSpec` and pre-registered by :func:`build_registry` — so
snapshots always contain the full catalog (deterministic shape even for
never-touched metrics), label schemas live in one place, and
``scripts/check_docs.py`` can ast-parse this file (no jax needed in the
lint lane) to enforce that ``docs/observability.md`` documents every
metric name.

Label values are drawn from closed sets only — ``status`` from
``RequestStatus``, ``site`` from ``FAULT_SITES``, ``kind`` from the two
retry kinds, ``layer``/``field`` from the model's layer pattern — which
is what makes the registry's cardinality bounds meaningful.
"""

from __future__ import annotations

from dataclasses import dataclass

from .registry import DEFAULT_BUCKETS, Registry

__all__ = ["MetricSpec", "METRICS", "build_registry"]

# bucket ladders ------------------------------------------------------------
_SECONDS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
            0.5, 1.0, 2.5, 5.0, 10.0)
_TOKENS = (16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0)
_RATIO = (1e-4, 3e-4, 1e-3, 3e-3, 0.01, 0.03, 0.1, 0.3, 1.0)
_DRIFT = (1e-3, 3e-3, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0)


@dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str                     # "counter" | "gauge" | "histogram"
    help: str
    labels: tuple = ()
    buckets: tuple = ()
    max_label_sets: int = 64


METRICS = (
    # -- request lifecycle (scheduler) -------------------------------------
    MetricSpec("serving_requests_submitted_total", "counter",
               "Requests accepted by Scheduler.submit (excludes shed)."),
    MetricSpec("serving_requests_shed_total", "counter",
               "Submits rejected at admission by the AdmissionValve."),
    MetricSpec("serving_results_total", "counter",
               "Terminal results by RequestStatus value.", ("status",)),
    MetricSpec("serving_retries_total", "counter",
               "Retry attempts by kind (admission | decode).", ("kind",)),
    MetricSpec("serving_quarantine_total", "counter",
               "Numeric-guard quarantine hits (NaN/Inf compressed chunks)."),
    MetricSpec("serving_faults_injected_total", "counter",
               "FaultInjector firings by site.", ("site",), max_label_sets=16),
    MetricSpec("serving_decode_steps_total", "counter",
               "Jitted decode steps executed by run_continuous."),
    MetricSpec("serving_tokens_generated_total", "counter",
               "Tokens sampled across all slots (decode only)."),
    MetricSpec("serving_queue_depth", "gauge",
               "Requests waiting in the scheduler queue."),
    MetricSpec("serving_prefill_seconds", "histogram",
               "Per-request prefill latency (includes splice).",
               buckets=_SECONDS),
    MetricSpec("serving_decode_step_seconds", "histogram",
               "Per-step decode latency across the active batch.",
               buckets=_SECONDS),
    MetricSpec("serving_queue_wait_seconds", "histogram",
               "Submit-to-prefill queue wait.", buckets=_SECONDS),
    MetricSpec("serving_prefill_bucket_tokens", "histogram",
               "Padded prefill bucket size in tokens (raw length when "
               "bucketing is off).", buckets=_TOKENS),
    # -- paged pool --------------------------------------------------------
    MetricSpec("pool_admits_total", "counter",
               "Successful PagePool.admit reservations."),
    MetricSpec("pool_rejects_total", "counter",
               "PagePool.admit failures (PoolExhausted)."),
    MetricSpec("pool_shared_pages_total", "counter",
               "Pages admitted by refcount bump (prefix hits)."),
    MetricSpec("pool_fresh_pages_total", "counter",
               "Pages allocated fresh from the free list."),
    MetricSpec("pool_freed_pages_total", "counter",
               "Pages whose refcount dropped to zero and were freed."),
    MetricSpec("pool_free_pages", "gauge", "Pages currently free."),
    MetricSpec("pool_used_pages", "gauge", "Pages currently referenced."),
    # -- prefix cache ------------------------------------------------------
    MetricSpec("prefix_lookup_chunks_total", "counter",
               "Chunks requested across trie lookups."),
    MetricSpec("prefix_hit_chunks_total", "counter",
               "Chunks served from the trie."),
    MetricSpec("prefix_inserts_total", "counter",
               "Chunks inserted into the trie."),
    MetricSpec("prefix_evictions_total", "counter",
               "Chunks evicted under the byte budget."),
    MetricSpec("prefix_expiries_total", "counter",
               "Chunks pruned by TTL expiry."),
    MetricSpec("prefix_version_evictions_total", "counter",
               "Chunks invalidated by weight-version bumps."),
    MetricSpec("prefix_toks_saved_total", "counter",
               "Prefill tokens skipped thanks to prefix hits."),
    MetricSpec("prefix_validate_failures_total", "counter",
               "ChunkStore.put rejections of non-finite payloads."),
    MetricSpec("prefix_nodes", "gauge", "Live trie nodes."),
    MetricSpec("prefix_bytes", "gauge", "Payload bytes pinned by the trie."),
    # -- fidelity probes ---------------------------------------------------
    MetricSpec("fidelity_probes_total", "counter",
               "Fidelity probes executed (sampled prefills)."),
    MetricSpec("fidelity_probe_skipped_total", "counter",
               "Probes skipped by the overhead budget throttle."),
    MetricSpec("fidelity_probe_errors_total", "counter",
               "Probes that raised (swallowed; serving unaffected)."),
    MetricSpec("fidelity_sampled_chunks_total", "counter",
               "Closed chunks covered by probes, per layer.", ("layer",),
               max_label_sets=256),
    MetricSpec("fidelity_rel_err", "histogram",
               "Per-layer relative Frobenius error of reconstructed K/V "
               "vs the fp16 shadow prefill.", ("field", "layer"),
               _RATIO, max_label_sets=512),
    MetricSpec("fidelity_lowrank_share", "histogram",
               "Low-rank residual share of the reconstruction norm.",
               ("field", "layer"), _RATIO, max_label_sets=512),
    MetricSpec("fidelity_outlier_mass", "histogram",
               "Sparse-outlier share of the reconstruction norm.",
               ("field", "layer"), _RATIO, max_label_sets=512),
    MetricSpec("fidelity_logits_drift", "histogram",
               "Max-abs last-position logits drift vs the fp16 shadow.",
               buckets=_DRIFT),
    MetricSpec("fidelity_probe_seconds", "histogram",
               "Wall time spent inside each probe.", buckets=_SECONDS),
)


def build_registry(clock=None) -> Registry:
    """A :class:`Registry` with the full catalog pre-registered."""
    reg = Registry(clock=clock)
    for m in METRICS:
        if m.kind == "counter":
            reg.counter(m.name, m.help, m.labels, m.max_label_sets)
        elif m.kind == "gauge":
            reg.gauge(m.name, m.help, m.labels, m.max_label_sets)
        elif m.kind == "histogram":
            reg.histogram(m.name, m.help, m.labels,
                          m.buckets or DEFAULT_BUCKETS, m.max_label_sets)
        else:  # pragma: no cover - catalog is literal
            raise ValueError(f"unknown metric kind {m.kind!r}")
    return reg
