"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 50 \
      --batch 8 --seq 256 [--smoke] [--mesh 2x2] [--powersgd]

On a real TPU slice the mesh comes from the runtime topology
(``make_production_mesh``); on CPU pass ``--mesh dxm`` with
XLA_FLAGS=--xla_force_host_platform_device_count set, or omit for one device.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models.model import build_model
from repro.train.loop import train_loop
from repro.train.state import RunConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mesh", default="", help="e.g. 2x2 or 2x2x2 (pod,data,model)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--powersgd", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=200)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh:
        dims = [int(v) for v in args.mesh.split("x")]
        mesh = make_test_mesh(*dims[-2:], pod=dims[0] if len(dims) == 3 else 0)
    else:
        mesh = make_test_mesh(1, 1)

    run = RunConfig(
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        microbatches=args.microbatches, remat=True, remat_policy="dots",
        zero1=not args.no_zero1,
        grad_compression="powersgd" if args.powersgd else "none",
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, log_every=10)
    dc = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    state = train_loop(model, mesh, run, dc)
    print(f"done at step {int(state.step)}")


if __name__ == "__main__":
    main()
