import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Re-derive loop-aware costs + roofline for existing dry-run JSONs by
re-tracing each cell (no recompile — collective bytes are reused)."""

import glob
import json

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import OUT_DIR, build_cell
from repro.launch.mesh import make_production_mesh
from repro.perf.jaxpr_cost import trace_cost
from repro.perf.roofline import model_flops, roofline


def main():
    meshes = {"16x16": make_production_mesh(),
              "2x16x16": make_production_mesh(multi_pod=True)}
    cache = {}
    for path in sorted(glob.glob(os.path.join(os.path.abspath(OUT_DIR), "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "error" in rec:
            continue
        key = (rec["arch"], rec["shape"], rec.get("policy", "gear_kcvt4"))
        if key in cache:
            lc = cache[key]
        else:
            mesh = meshes[rec["mesh"]]
            with mesh:
                fn, args = build_cell(rec["arch"], rec["shape"], mesh, key[2])
                lc = trace_cost(fn, *args)
            cache[key] = lc
        cfg = get_config(rec["arch"])
        mf = model_flops(cfg, SHAPES[rec["shape"]])
        rl = roofline(lc["flops"], lc["bytes"], rec["collective_bytes"],
                      rec["chips"], mf)
        rec["loop_cost"] = lc
        rec["roofline"] = rl.row()
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        r = rl.row()
        print(f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
              f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
              f"x={r['collective_s']:.2e} -> {r['bottleneck']} eff={r['flops_eff']:.2f}")


if __name__ == "__main__":
    main()
