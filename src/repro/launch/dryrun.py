import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware: the sharding config is
coherent (SPMD partitioning succeeds), the program fits per-device HBM
(memory_analysis), and yields the roofline inputs (cost_analysis + HLO
collective traffic).  Results land in ``experiments/dryrun/`` as JSON, one
file per cell, and a printed summary row.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--policy gear_kcvt4]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, ARCHS, SHAPES, get_config, shapes_for
from repro.configs.base import ShapeConfig
from repro.core.policy import named_policy
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model, input_specs
from repro.perf.hlo_stats import collective_stats, op_histogram
from repro.perf.jaxpr_cost import trace_cost
from repro.perf.roofline import model_flops, roofline
from repro.train.state import RunConfig, init_train_state
from repro.train.loop import make_train_step, train_state_shardings

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _mem_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes",
            "peak_memory_in_bytes")
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or k.startswith("utilization"))}


def build_cell(arch: str, shape_name: str, mesh,
               policy_name: str = "gear_kcvt4", microbatches: int = 8):
    """Returns (callable, abstract args, shardings-applied jit fn builder)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    policy = named_policy(policy_name)

    if shape.mode == "train":
        run = RunConfig(microbatches=microbatches, remat=True, remat_policy="dots",
                        zero1=True, ckpt_every=0)
        state_abs = jax.eval_shape(
            lambda: init_train_state(
                jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))), run))
        st_shard = train_state_shardings(cfg, mesh, state_abs, run)
        batch_abs = input_specs(cfg, shape)
        b_shard = shd.shardings_for(mesh, shd.batch_pspecs(cfg, batch_abs, mesh))
        step = make_train_step(model, mesh, run, st_shard, b_shard)
        return step, (state_abs, batch_abs)
    if shape.mode == "prefill":
        params_abs = model.init_abstract()
        p_shard = shd.shardings_for(mesh, shd.param_pspecs(cfg, params_abs, mesh))
        batch_abs = input_specs(cfg, shape)
        b_shard = shd.shardings_for(mesh, shd.batch_pspecs(cfg, batch_abs, mesh))
        cap = shape.seq_len
        cache_abs = jax.eval_shape(
            lambda: model.init_caches(policy, shape.global_batch, cap))
        c_shard = shd.shardings_for(
            mesh, shd.cache_pspecs(cfg, cache_abs, mesh, shape.global_batch))
        fn = jax.jit(lambda p, b: model.prefill(p, b, policy, cap),
                     in_shardings=(p_shard, b_shard),
                     out_shardings=(None, c_shard))
        return fn, (params_abs, batch_abs)
    params_abs = model.init_abstract()
    p_shard = shd.shardings_for(mesh, shd.param_pspecs(cfg, params_abs, mesh))
    cap = shape.seq_len
    cache_abs = jax.eval_shape(
        lambda: model.init_caches(policy, shape.global_batch, cap))
    c_shard = shd.shardings_for(
        mesh, shd.cache_pspecs(cfg, cache_abs, mesh, shape.global_batch))
    batch_abs = input_specs(cfg, shape)
    b_shard = shd.shardings_for(mesh, shd.batch_pspecs(cfg, batch_abs, mesh))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    fn = jax.jit(
        lambda p, tok, caches, pos: model.decode_step(p, tok, caches, pos,
                                                      policy, cap),
        in_shardings=(p_shard, b_shard, c_shard, None),
        out_shardings=(None, c_shard),
        donate_argnums=(2,))
    return fn, (params_abs, batch_abs, cache_abs, pos_abs)


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                policy_name: str = "gear_kcvt4", microbatches: int = 8) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    with mesh:
        fn, args = build_cell(arch, shape_name, mesh, policy_name, microbatches)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        loop_cost = trace_cost(fn, *args)

    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    cost = _cost(compiled)
    mem = _mem_summary(compiled)
    mf = model_flops(cfg, shape)
    # XLA's CPU cost_analysis counts while bodies once; the jaxpr-derived
    # loop-aware cost is the roofline input (see perf/jaxpr_cost.py).
    rl = roofline(loop_cost["flops"], loop_cost["bytes"],
                  coll["total_operand_bytes"], chips, mf)

    record = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "policy": policy_name,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_xla_raw": cost, "loop_cost": loop_cost, "memory": mem,
        "collectives": {k: v for k, v in coll.items() if k != "total_operand_bytes"},
        "collective_bytes": coll["total_operand_bytes"],
        "roofline": rl.row(),
        "op_histogram": op_histogram(hlo),
    }
    return record


def run_cells(cells, multi_pod: bool, policy: str, out_dir: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch, shape_name in cells:
        mesh_tag = "2x16x16" if multi_pod else "16x16"
        fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
        if os.path.exists(fname):
            with open(fname) as f:
                rec = json.load(f)
            results.append(rec)
            print(f"[skip] {arch} × {shape_name} × {mesh_tag} (cached)")
            continue
        try:
            rec = dryrun_cell(arch, shape_name, multi_pod, policy)
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
            r = rec["roofline"]
            print(f"[ok]   {arch} × {shape_name} × {mesh_tag}: "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s → {r['bottleneck']} "
                  f"(compile {rec['compile_s']}s)")
            results.append(rec)
        except Exception as e:
            print(f"[FAIL] {arch} × {shape_name} × {mesh_tag}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=6)
            results.append({"arch": arch, "shape": shape_name, "error": str(e)})
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="gear_kcvt4")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    if args.all:
        cells = [(a, s.name) for a in ARCHS for s in shapes_for(a)]
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else [s.name for s in SHAPES.values()]
        cells = [(a, s) for a in archs for s in shapes
                 if any(sc.name == s for sc in shapes_for(a))]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        run_cells(cells, mp, args.policy, args.out)


if __name__ == "__main__":
    main()
