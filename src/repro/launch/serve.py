"""Serving launcher: batched generation with a GEAR-compressed cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --policy gear_kcvt4 --batch 4 --prompt 64 --gen 32

Built entirely on the public :mod:`repro.serving` API.  ``--mode wave``
drives :meth:`Engine.generate` lockstep; ``--mode continuous`` submits
per-prompt :class:`Request` objects to :class:`Scheduler.run_continuous`.
``--layout paged`` serves from the pooled compressed-chunk page layout
(continuous mode only — pages are reserved per request, so concurrency is
pool-bytes-limited instead of slot-count-limited).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.policy import named_policy
from repro.launch.mesh import make_test_mesh
from repro.models.model import build_model
from repro.serving import (CacheLayout, Engine, EngineConfig, ObsConfig,
                           Request, Scheduler)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--policy", default="gear_kcvt4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--buffer", type=int, default=0, help="override n_b")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mode", default="wave", choices=["wave", "continuous"])
    ap.add_argument("--layout", default="dense", choices=["dense", "paged"])
    ap.add_argument("--pool-bytes", type=int, default=0,
                    help="paged: pool device-byte budget (default: dense-"
                         "equivalent batch*n_chunks pages)")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous: queued requests (default 2*batch)")
    ap.add_argument("--obs", action="store_true",
                    help="enable serving telemetry (metrics + traces)")
    ap.add_argument("--fidelity-every", type=int, default=0,
                    help="obs: probe every Nth closed chunk (0 = off)")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics registry snapshot here (JSON; "
                         "implies --obs)")
    ap.add_argument("--trace-out", default="",
                    help="write Chrome trace_event JSON here (implies --obs)")
    args = ap.parse_args()
    if args.metrics_json or args.trace_out or args.fidelity_every:
        args.obs = True

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    pol = named_policy(args.policy)
    if args.buffer:
        pol = dataclasses.replace(pol, buffer_size=args.buffer,
                                  group=min(pol.group, args.buffer))
    layout = CacheLayout(args.layout)
    if layout is CacheLayout.PAGED and args.mode == "wave":
        args.mode = "continuous"   # paged serves through continuous batching
    if args.obs and args.mode == "wave":
        args.mode = "continuous"   # traces span the request lifecycle
    mesh = None
    if args.mesh:
        dims = [int(v) for v in args.mesh.split("x")]
        mesh = make_test_mesh(*dims)

    params = model.init(jax.random.PRNGKey(0))
    cap = args.prompt + args.gen + (cfg.num_prefix_tokens if cfg.modality == "vlm" else 0)
    obs_cfg = (ObsConfig(fidelity_every_n=max(args.fidelity_every, 0))
               if args.obs else None)
    eng = Engine(model, params,
                 EngineConfig(batch=args.batch, capacity=cap, policy=pol,
                              temperature=args.temperature, layout=layout,
                              pool_bytes=args.pool_bytes, obs=obs_cfg),
                 mesh=mesh)
    key = jax.random.PRNGKey(1)

    if args.mode == "continuous":
        if cfg.modality != "text":
            raise SystemExit("continuous mode drives text tokens")
        sched = Scheduler(eng)
        n_req = args.requests or 2 * args.batch
        # genuinely mixed-length raw prompts (--prompt is the longest): the
        # engine length-buckets each one internally, no scheduler padding
        lo = max(1, args.prompt // 2)
        for rid in range(n_req):
            plen = lo + rid % (args.prompt - lo + 1)
            toks = np.asarray(jax.random.randint(
                jax.random.fold_in(key, rid), (plen,), 0, cfg.vocab_size))
            sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=args.gen))
        results = sched.run_continuous()
        st = sched.last_stats
        line = (f"served {len(results)} requests ({st['tokens']} tokens) in "
                f"{st['wall_s']:.2f}s; attend={st['attend_path']} "
                f"layout={st['layout']}")
        if "pool" in st:
            p = st["pool"]
            line += (f"; pool {p['used_pages']}/{p['used_pages'] + p['free_pages']}"
                     f" pages used, {p['shared_pages']} shared")
        print(line)
        if eng.obs is not None:
            cov = eng.obs.tracer.coverage([r.rid for r in results])
            line = (f"obs: traces {len(cov['statuses'])}/{len(results)} rids"
                    f" complete={cov['complete']}")
            if eng.obs.fidelity is not None:
                line += f", fidelity probes {len(eng.obs.fidelity.reports)}"
            print(line)
            if args.metrics_json:
                eng.obs.write_metrics_json(args.metrics_json)
                print(f"obs: metrics snapshot -> {args.metrics_json}")
            if args.trace_out:
                eng.obs.write_trace(args.trace_out)
                print(f"obs: chrome trace -> {args.trace_out}")
        return

    if cfg.modality == "audio":
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt,
                                                    cfg.num_codebooks), 0, cfg.vocab_size)}
    elif cfg.modality == "vlm":
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt), 0, cfg.vocab_size),
                 "img_embeds": jax.random.normal(key, (args.batch, cfg.num_prefix_tokens,
                                                       cfg.d_model), jnp.bfloat16)}
    else:
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt), 0, cfg.vocab_size)}
    toks, stats = eng.generate(batch, args.gen)
    print(f"generated {toks.shape}; prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s ({stats['decode_tok_per_s']:.1f} tok/s), "
          f"cache {stats['cache_bytes']/1e6:.1f} MB")


if __name__ == "__main__":
    main()
