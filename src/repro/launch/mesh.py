"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512
host devices via XLA_FLAGS before first jax init, while tests/benches must
see the real single device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes", "DATA", "MODEL", "POD"]

POD, DATA, MODEL = "pod", "data", "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD, DATA, MODEL) if multi_pod else (DATA, MODEL)
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), (POD, DATA, MODEL))
    return jax.make_mesh((data, model), (DATA, MODEL))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in (POD, DATA))
