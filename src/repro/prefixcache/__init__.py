"""Radix-trie prefix cache over compressed GEAR chunks.

Cross-request prefill reuse (vLLM automatic-prefix-caching / SGLang
RadixAttention, adapted to the compressed cache): requests sharing a
chunk-aligned prompt prefix reuse the prefix's *compressed* chunks instead
of recomputing prefill attention + compression.  Because every
``n_b``-token chunk is compressed as an independent, slot-invariant event
(DESIGN.md §2), a cached chunk is bit-identical to the chunk the request
would have computed itself — splicing from the cache adds **zero**
approximation drift on top of GEAR's near-lossless recipe, and suffix
prefill over the spliced prefix reproduces the cold run's cache and logits
bit for bit (DESIGN.md §4).

Layering:

* :mod:`~repro.prefixcache.trie` — chunk-granular radix trie: longest-match
  lookup, LRU eviction under a byte budget, refcount pinning, stats;
* :mod:`~repro.prefixcache.store` — payload store + engine-tree
  extraction/splicing built on the :mod:`repro.core.cache` chunk APIs;
* :class:`PrefixCache` — the facade the serving engine drives
  (:meth:`repro.serving.engine.Engine.prefill_slot`).
"""

from __future__ import annotations

import dataclasses

from repro.prefixcache.store import (ChunkStore, chunk_keys, payload_nbytes,
                                     extract_tree_chunks, splice_tree_chunks)
from repro.prefixcache.trie import RadixTrie, TrieNode, TrieStats

__all__ = ["PrefixCache", "PrefixMatch", "PrefixSnapshot", "RadixTrie",
           "TrieNode", "TrieStats", "ChunkStore", "chunk_keys",
           "payload_nbytes", "extract_tree_chunks", "splice_tree_chunks"]


@dataclasses.dataclass(frozen=True)
class PrefixSnapshot:
    """Typed point-in-time view of a :class:`PrefixCache` (trie stats +
    store health) — what ``Scheduler.last_stats`` diffs for its per-run
    prefix counters.  Indexing delegates to attributes for dict-style
    consumers."""

    prefix_hit_rate: float
    prefill_toks_saved: int
    lookups: int
    hits: int
    misses: int
    hit_chunks: int
    lookup_chunks: int
    inserts: int
    evictions: int
    expiries: int
    version_evictions: int
    validate_failures: int
    nodes: int
    bytes: int
    budget_bytes: int

    def __getitem__(self, key: str):
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PrefixMatch:
    """A pinned longest-prefix hit: release via :meth:`PrefixCache.release`."""

    nodes: list[TrieNode]
    payloads: list            # one engine-tree chunk payload per matched chunk

    @property
    def n_chunks(self) -> int:
        return len(self.nodes)


class PrefixCache:
    """Trie + store facade keyed on ``chunk``-token id chunks.

    ``store`` is pluggable: the default :class:`ChunkStore` owns host-side
    payload copies; the paged engine passes a
    :class:`~repro.serving.pagedpool.PagePoolStore`, whose handles are pool
    page ids — then an insert is a refcount bump (the chunk's device bytes
    are already in the pool) and an eviction releases the page.  A custom
    store may provide ``nbytes_of(payload)``, used instead of
    :func:`payload_nbytes` so the trie's LRU budget prices entries in the
    store's own byte units (exact page bytes for the pool).

    Lifecycle knobs (threaded from
    :class:`~repro.serving.engine.EngineConfig`):

    * ``ttl`` — seconds a cached chunk stays valid from *insert* (0
      disables expiry; hits do not refresh it);
    * ``eviction`` — ``"lru"`` (default) or ``"lfu"`` budget-pressure
      victim policy;
    * ``clock`` — injectable monotonic-seconds source (tests);
    * :meth:`bump_version` — invalidates every cached chunk at once (the
      engine calls it on a weight swap: chunks compressed under old
      weights must never be spliced into a new-weights prefill).

    Staleness is enforced lazily at the next walk that touches a stale
    node; the pruned payloads are freed here the moment they are drained
    from the trie, and show up in :attr:`stats` under ``expiries`` /
    ``version_evictions``.
    """

    def __init__(self, chunk: int, budget_bytes: int, store=None,
                 ttl: float = 0.0, eviction: str = "lru", clock=None,
                 validate: bool = False):
        self.chunk = int(chunk)
        self.trie = RadixTrie(budget_bytes, ttl=ttl, eviction=eviction,
                              clock=clock)
        self.store = ChunkStore(validate=validate) if store is None else store
        self._nbytes_of = getattr(self.store, "nbytes_of", payload_nbytes)
        self.toks_saved = 0

    def bump_version(self) -> None:
        """Invalidate all cached chunks (see :meth:`RadixTrie.bump_version`)."""
        self.trie.bump_version()

    def _drain_pruned(self) -> None:
        for handle in self.trie.drain_pruned():
            self.store.free(handle)

    # ------------------------------------------------------------------
    def match(self, tokens, max_chunks: int | None = None) -> PrefixMatch:
        """Longest cached chunk-aligned prefix of ``tokens``.

        Pins the matched path (the caller must :meth:`release` after
        splicing) and accounts the reuse in ``toks_saved``.  ``max_chunks``
        caps the match — the engine always leaves at least one suffix
        token so prefill still produces last-position logits.
        """
        keys = chunk_keys(tokens, self.chunk)
        if max_chunks is not None:
            keys = keys[:max_chunks]
        nodes = self.trie.lookup(keys, acquire=True)
        self._drain_pruned()
        self.toks_saved += len(nodes) * self.chunk
        return PrefixMatch(nodes=nodes,
                           payloads=[self.store.get(nd.handle) for nd in nodes])

    def release(self, match: PrefixMatch) -> None:
        self.trie.release(match.nodes)

    def insert(self, tokens, payloads, start_chunk: int = 0) -> int:
        """Cache ``payloads`` as chunks ``[start_chunk, ...)`` of ``tokens``.

        The first ``start_chunk`` chunks must already be cached (the warm
        request's matched — still pinned — prefix).  Duplicate chunks (a
        racing insert) and any LRU evictions are freed from the store.
        Returns the number of nodes created.
        """
        keys = chunk_keys(tokens, self.chunk)[:start_chunk + len(payloads)]
        entries = ([None] * start_chunk
                   + [(self.store.put(p), self._nbytes_of(p)) for p in payloads])
        created, unused, evicted = self.trie.insert(keys, entries)
        self._drain_pruned()
        for handle in unused:
            self.store.free(handle)
        for handle in evicted:
            self.store.free(handle)
        return len(created)

    def clear(self) -> None:
        """Drop all cached chunks (keeps budget and stats counters)."""
        for handle in self.trie.clear():
            self.store.free(handle)
        self._drain_pruned()

    def evict_bytes(self, n_bytes: int) -> int:
        """Evict least-recently-used unpinned entries until at least
        ``n_bytes`` have been reclaimed (or nothing evictable remains).

        The paged scheduler's deadlock valve: when every slot is idle, the
        queue is non-empty, and admission still fails, the pool's free
        pages are all pinned by the trie — reclaiming here turns trie
        references back into allocatable pages.  Returns bytes reclaimed.
        Implemented by temporarily lowering the trie's budget and running
        its normal LRU eviction, so pinned-path protection and stats
        behave exactly as budget-pressure evictions do.
        """
        before = self.trie.total_bytes
        budget = self.trie.budget_bytes
        self.trie.budget_bytes = max(before - n_bytes, 0)
        try:
            for handle in self.trie.evict_to_budget():
                self.store.free(handle)
        finally:
            self.trie.budget_bytes = budget
        return before - self.trie.total_bytes

    def live_handles(self) -> list:
        """Payload handles the trie owns (see :meth:`RadixTrie.live_handles`)."""
        return self.trie.live_handles()

    def audit(self) -> dict:
        """Trie structural audit (see :meth:`RadixTrie.audit`)."""
        return self.trie.audit()

    # ------------------------------------------------------------------
    def snapshot(self) -> PrefixSnapshot:
        """Typed snapshot (see :class:`PrefixSnapshot`)."""
        st = self.trie.stats
        return PrefixSnapshot(
            prefix_hit_rate=st.prefix_hit_rate,
            prefill_toks_saved=self.toks_saved,
            lookups=st.lookups, hits=st.hits, misses=st.misses,
            hit_chunks=st.hit_chunks, lookup_chunks=st.lookup_chunks,
            inserts=st.inserts, evictions=st.evictions,
            expiries=st.expiries, version_evictions=st.version_evictions,
            validate_failures=getattr(self.store, "validate_failures", 0),
            nodes=self.trie.n_nodes, bytes=self.trie.total_bytes,
            budget_bytes=self.trie.budget_bytes)

    @property
    def stats(self) -> dict:
        st = self.trie.stats
        return {
            "prefix_hit_rate": st.prefix_hit_rate,
            "prefill_toks_saved": self.toks_saved,
            "lookups": st.lookups,
            "hits": st.hits,
            "misses": st.misses,
            "hit_chunks": st.hit_chunks,
            "lookup_chunks": st.lookup_chunks,
            "inserts": st.inserts,
            "evictions": st.evictions,
            "expiries": st.expiries,
            "version_evictions": st.version_evictions,
            "nodes": self.trie.n_nodes,
            "bytes": self.trie.total_bytes,
            "budget_bytes": self.trie.budget_bytes,
        }
