"""Compressed-chunk store + engine-tree chunk extraction/splicing.

A chunk **payload** is everything needed to reproduce one ``n_b``-token
GEAR chunk in any slot of any same-geometry cache: a tuple over the
model's pattern positions of per-layer field dicts (packed quant codes,
per-chunk quant stats, low-rank factors, outliers — see
:func:`repro.core.cache.extract_prefix_chunks`).  Payload leaves are
device arrays extracted straight from a batch-1 prefill's cache tree, so a
hit is spliced back with plain ``dynamic_update_slice`` writes and zero
recompression.

:class:`ChunkStore` owns the payloads behind opaque integer handles (the
radix trie stores only handles + byte sizes) and does exact byte
accounting — the number the trie's LRU budget governs.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.core import cache as cache_lib

__all__ = ["ChunkStore", "chunk_keys", "payload_nbytes",
           "extract_tree_chunks", "splice_tree_chunks"]


def chunk_keys(tokens, chunk: int) -> list[tuple[int, ...]]:
    """Trie edge labels for a prompt: its full ``chunk``-token chunks."""
    toks = [int(t) for t in tokens]
    n_full = len(toks) // chunk
    return [tuple(toks[c * chunk:(c + 1) * chunk]) for c in range(n_full)]


def payload_nbytes(payload) -> int:
    """Exact device bytes of one chunk payload."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(payload))


class ChunkStore:
    """Handle-addressed payload store with exact byte accounting.

    The store protocol the :class:`repro.prefixcache.PrefixCache` facade
    drives — ``put``/``get``/``free`` plus the ``nbytes_of`` pricing hook —
    is also implemented by :class:`repro.serving.pagedpool.PagePoolStore`,
    where handles are pool page ids rather than host copies.

    ``validate=True`` adds numeric quarantine at the insert boundary: a
    payload with any NaN/Inf leaf raises
    :class:`~repro.core.cache.NumericFault` instead of being stored, so a
    poisoned chunk can never be served to a later warm request.  (The
    serving engine guards at prefill time, before chunks reach here; the
    store-level check is the defense for direct :class:`PrefixCache`
    users and the host-copy store path.)
    """

    def __init__(self, validate: bool = False):
        self.validate = bool(validate)
        self._entries: dict[int, tuple[Any, int]] = {}
        self._next_handle = 0
        self.total_bytes = 0
        self.validate_failures = 0  # NumericFault rejections at put()

    @staticmethod
    def nbytes_of(payload) -> int:
        return payload_nbytes(payload)

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, payload) -> int:
        if self.validate and not bool(cache_lib.tree_finite(payload)):
            self.validate_failures += 1
            raise cache_lib.NumericFault(
                "chunk payload holds NaN/Inf; refusing to cache it")
        handle = self._next_handle
        self._next_handle += 1
        nbytes = payload_nbytes(payload)
        self._entries[handle] = (payload, nbytes)
        self.total_bytes += nbytes
        return handle

    def get(self, handle: int):
        return self._entries[handle][0]

    def free(self, handle: int) -> None:
        _, nbytes = self._entries.pop(handle)
        self.total_bytes -= nbytes


# ---------------------------------------------------------------------------
# Engine cache tree <-> per-chunk payloads


def extract_tree_chunks(cache_cfgs, caches, c_lo: int, c_hi: int) -> list:
    """Slice chunks ``[c_lo, c_hi)`` out of an engine cache tree.

    ``caches`` is the engine layout — a tuple over pattern positions of
    layer caches with repeat-stacked ``[R, B, ...]`` leaves (a batch-1
    prefill result in practice); ``cache_cfgs`` the matching per-position
    :class:`~repro.core.cache.CacheConfig` list.  Returns one payload per
    chunk: a tuple over positions of that chunk's field dicts.
    """
    per_pos = [cache_lib.extract_prefix_chunks(cfg, layer, c_hi - c_lo, c_lo)
               for cfg, layer in zip(cache_cfgs, caches)]
    return [tuple(chunks[c] for chunks in per_pos) for c in range(c_hi - c_lo)]


def splice_tree_chunks(cache_cfgs, caches, slot, payloads,
                       start_chunk: int = 0, batch_axis: int = 1):
    """Write per-chunk payloads into batch row ``slot`` of an engine cache
    tree as chunks ``[start_chunk, start_chunk + len(payloads))`` — the
    prefix-cache half of the slot-splice protocol (DESIGN.md §4)."""
    out = []
    for i, (cfg, layer) in enumerate(zip(cache_cfgs, caches)):
        out.append(cache_lib.splice_prefix_chunks(
            cfg, layer, slot, [p[i] for p in payloads], start_chunk,
            batch_axis=batch_axis))
    return tuple(out)
