"""Chunk-granular radix trie for cross-request prefix matching.

The trie is keyed on ``n_b``-aligned token-id chunks: every edge is one
whole chunk (a tuple of ``n_b`` token ids), so a root-to-node path spells a
chunk-aligned prompt prefix and each node owns exactly one compressed-chunk
payload (held in :class:`repro.prefixcache.store.ChunkStore`; the trie only
sees an opaque ``handle`` plus its byte size).  Chunk granularity is what
makes cached state spliceable: GEAR compresses each ``n_b``-token chunk as
an independent, slot-invariant event, so a chunk-aligned prefix has
bit-identical compressed form no matter which request computed it — a
finer-grained (per-token) trie would name state the cache layout cannot
reproduce.

Eviction is LRU over *evictable leaves* under a byte budget: a node can be
evicted only when it has no children (an interior node is the prefix of a
longer cached path — dropping it would orphan descendants) and no live
references.  Callers pin a matched path with ``lookup(acquire=True)`` while
they splice its payloads and must :meth:`RadixTrie.release` it afterwards;
referenced nodes are never evicted, so the budget is a soft bound while
pins are outstanding and a hard bound otherwise.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Hashable, Iterable, Sequence

__all__ = ["RadixTrie", "TrieNode", "TrieStats"]


@dataclasses.dataclass
class TrieStats:
    """Monotonic counters; rates are derived properties."""

    lookups: int = 0        # lookup() calls
    hits: int = 0           # lookups matching >= 1 chunk
    misses: int = 0         # lookups matching 0 chunks
    hit_chunks: int = 0     # chunks served across all lookups
    lookup_chunks: int = 0  # chunks eligible across all lookups
    inserts: int = 0        # nodes created
    evictions: int = 0      # nodes evicted

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of eligible prompt chunks served from the trie."""
        return self.hit_chunks / max(self.lookup_chunks, 1)


class TrieNode:
    """One cached chunk: edge label ``key`` + opaque payload ``handle``."""

    __slots__ = ("key", "parent", "children", "handle", "nbytes", "refs",
                 "last_use")

    def __init__(self, key: Hashable, parent: "TrieNode | None",
                 handle: Any = None, nbytes: int = 0):
        self.key = key
        self.parent = parent
        self.children: dict[Hashable, TrieNode] = {}
        self.handle = handle
        self.nbytes = int(nbytes)
        self.refs = 0
        self.last_use = 0


class RadixTrie:
    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self.root = TrieNode(key=None, parent=None)
        self.total_bytes = 0
        self.n_nodes = 0
        self.stats = TrieStats()
        self._clock = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------------
    def lookup(self, chunk_keys: Sequence[Hashable],
               acquire: bool = False) -> list[TrieNode]:
        """Longest chunk-aligned prefix match.

        Returns the node path for the longest prefix of ``chunk_keys``
        present in the trie (empty list on a total miss) and bumps every
        matched node's LRU recency.  ``acquire=True`` additionally pins
        each node on the path (refcount +1) so eviction cannot free a
        payload the caller is about to splice; the caller must
        :meth:`release` the same list when done.
        """
        self.stats.lookups += 1
        self.stats.lookup_chunks += len(chunk_keys)
        t = self._tick()
        node, path = self.root, []
        for key in chunk_keys:
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = t
            path.append(child)
            node = child
        if acquire:
            for nd in path:
                nd.refs += 1
        self.stats.hit_chunks += len(path)
        if path:
            self.stats.hits += 1
        else:
            self.stats.misses += 1
        return path

    def release(self, nodes: Iterable[TrieNode]) -> None:
        """Unpin nodes previously acquired by ``lookup(acquire=True)``."""
        for nd in nodes:
            if nd.refs <= 0:
                raise ValueError("release without matching acquire")
            nd.refs -= 1

    # ------------------------------------------------------------------
    def insert(self, chunk_keys: Sequence[Hashable],
               entries: Sequence[tuple[Any, int] | None]):
        """Insert/extend one chunk path.

        ``entries[i]`` is ``(handle, nbytes)`` for chunk ``i``, or None when
        the caller expects the node to already exist (e.g. the matched
        prefix of a warm request).  Walks the path, creating nodes where
        missing; stops early if a node is missing but its entry is None.
        Returns ``(created, unused_handles, evicted_handles)``: handles the
        trie did not take ownership of (a racing insert already cached that
        chunk) plus handles freed by the post-insert eviction pass — the
        caller must free both sets in its payload store.
        """
        if len(entries) != len(chunk_keys):
            raise ValueError(f"{len(entries)} entries for {len(chunk_keys)} keys")
        t = self._tick()
        node = self.root
        created: list[TrieNode] = []
        unused: list[Any] = []
        for i, (key, entry) in enumerate(zip(chunk_keys, entries)):
            child = node.children.get(key)
            if child is None:
                if entry is None:
                    # cannot extend past a missing unbacked node; hand every
                    # remaining provided handle back so the caller's store
                    # does not leak the orphaned payloads
                    unused.extend(e[0] for e in entries[i:] if e is not None)
                    break
                handle, nbytes = entry
                child = TrieNode(key, node, handle, nbytes)
                node.children[key] = child
                self.total_bytes += child.nbytes
                self.n_nodes += 1
                self.stats.inserts += 1
                created.append(child)
            elif entry is not None:
                unused.append(entry[0])
            child.last_use = t
            node = child
        return created, unused, self.evict_to_budget()

    # ------------------------------------------------------------------
    def _evictable_leaves(self) -> list[TrieNode]:
        out, stack = [], list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
            elif nd.refs == 0:
                out.append(nd)
        return out

    def evict_to_budget(self) -> list[Any]:
        """Evict LRU evictable leaves until within budget.

        Returns the payload handles freed (for the caller's store).  May
        leave the trie above budget when every remaining leaf is pinned —
        referenced nodes are never evicted.  One trie walk seeds a heap of
        evictable leaves; a victim's parent joins the heap the moment it
        becomes a childless unpinned leaf, so an eviction burst is
        O(nodes log nodes), not a full re-walk per victim.
        """
        evicted: list[Any] = []
        if self.total_bytes <= self.budget_bytes:
            return evicted
        heap = [(nd.last_use, id(nd), nd) for nd in self._evictable_leaves()]
        heapq.heapify(heap)
        while self.total_bytes > self.budget_bytes and heap:
            _, _, victim = heapq.heappop(heap)
            del victim.parent.children[victim.key]
            self.total_bytes -= victim.nbytes
            self.n_nodes -= 1
            self.stats.evictions += 1
            evicted.append(victim.handle)
            parent = victim.parent
            if (parent is not self.root and not parent.children
                    and parent.refs == 0):
                heapq.heappush(heap, (parent.last_use, id(parent), parent))
        return evicted

    def clear(self) -> list[Any]:
        """Drop every node (ignores pins — callers must hold none).
        Returns all payload handles for the caller's store."""
        handles = []
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            handles.append(nd.handle)
            stack.extend(nd.children.values())
        self.root.children.clear()
        self.total_bytes = 0
        self.n_nodes = 0
        return handles
