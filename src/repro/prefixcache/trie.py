"""Chunk-granular radix trie for cross-request prefix matching.

The trie is keyed on ``n_b``-aligned token-id chunks: every edge is one
whole chunk (a tuple of ``n_b`` token ids), so a root-to-node path spells a
chunk-aligned prompt prefix and each node owns exactly one compressed-chunk
payload (held in :class:`repro.prefixcache.store.ChunkStore`; the trie only
sees an opaque ``handle`` plus its byte size).  Chunk granularity is what
makes cached state spliceable: GEAR compresses each ``n_b``-token chunk as
an independent, slot-invariant event, so a chunk-aligned prefix has
bit-identical compressed form no matter which request computed it — a
finer-grained (per-token) trie would name state the cache layout cannot
reproduce.

Eviction is LRU (or LFU, ``eviction="lfu"``) over *evictable leaves* under
a byte budget: a node can be evicted only when it has no children (an
interior node is the prefix of a longer cached path — dropping it would
orphan descendants) and no live references.  Callers pin a matched path
with ``lookup(acquire=True)`` while they splice its payloads and must
:meth:`RadixTrie.release` it afterwards; referenced nodes are never
evicted, so the budget is a soft bound while pins are outstanding and a
hard bound otherwise.

Two staleness mechanisms guard cache *validity* on top of the capacity
budget:

* **TTL** — ``ttl`` seconds from node *creation* (hits do not refresh it;
  a compressed chunk does not get fresher by being popular);
* **versioning** — every node is stamped with the trie ``version`` at
  insert; :meth:`RadixTrie.bump_version` (driven by the engine on a weight
  swap) makes every existing node stale at once, since chunks compressed
  under old weights must never be spliced into a new-weights prefill.

Both are enforced *lazily*: a walk (lookup or insert) that steps onto a
stale node prunes that node's whole subtree instead of matching it.  The
pruned payload handles accumulate in ``pending_free`` — the facade drains
them via :meth:`RadixTrie.drain_pruned` and frees them in its store.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Hashable, Iterable, Sequence

__all__ = ["RadixTrie", "TrieNode", "TrieStats"]

_BLOCKED = object()   # stale child whose pruning a pin deferred


@dataclasses.dataclass
class TrieStats:
    """Monotonic counters; rates are derived properties."""

    lookups: int = 0        # lookup() calls
    hits: int = 0           # lookups matching >= 1 chunk
    misses: int = 0         # lookups matching 0 chunks
    hit_chunks: int = 0     # chunks served across all lookups
    lookup_chunks: int = 0  # chunks eligible across all lookups
    inserts: int = 0        # nodes created
    evictions: int = 0      # nodes evicted under byte-budget pressure
    expiries: int = 0       # nodes pruned past their TTL
    version_evictions: int = 0  # nodes pruned by a version bump

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of eligible prompt chunks served from the trie."""
        return self.hit_chunks / max(self.lookup_chunks, 1)


class TrieNode:
    """One cached chunk: edge label ``key`` + opaque payload ``handle``."""

    __slots__ = ("key", "parent", "children", "handle", "nbytes", "refs",
                 "last_use", "uses", "created_at", "version")

    def __init__(self, key: Hashable, parent: "TrieNode | None",
                 handle: Any = None, nbytes: int = 0,
                 created_at: float = 0.0, version: int = 0):
        self.key = key
        self.parent = parent
        self.children: dict[Hashable, TrieNode] = {}
        self.handle = handle
        self.nbytes = int(nbytes)
        self.refs = 0
        self.last_use = 0
        # LFU frequency: creation counts as the first use, so a fresh
        # insert is never its own eviction victim in the same call — it
        # ties with single-hit chunks and loses only to them on recency
        self.uses = 1
        self.created_at = created_at
        self.version = version


class RadixTrie:
    """See the module docstring.  ``ttl=0`` disables expiry; ``clock`` is
    an injectable monotonic-seconds source (tests pass a fake)."""

    def __init__(self, budget_bytes: int, ttl: float = 0.0,
                 eviction: str = "lru",
                 clock: Callable[[], float] | None = None):
        if eviction not in ("lru", "lfu"):
            raise ValueError(f"eviction must be 'lru' or 'lfu', got {eviction!r}")
        self.budget_bytes = int(budget_bytes)
        self.ttl = float(ttl)
        self.eviction = eviction
        self.clock = time.monotonic if clock is None else clock
        self.root = TrieNode(key=None, parent=None)
        self.total_bytes = 0
        self.n_nodes = 0
        self.version = 0
        self.stats = TrieStats()
        self.pending_free: list[Any] = []
        self._clock = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------------
    # staleness (TTL + weight version)

    def bump_version(self) -> None:
        """Invalidate every cached chunk (engine weight swap): nodes keep
        serving nothing — the next walk that reaches them prunes them."""
        self.version += 1

    def _stale(self, nd: TrieNode, now: float) -> bool:
        return (nd.version != self.version
                or (self.ttl > 0.0 and now - nd.created_at > self.ttl))

    def _prune_subtree(self, nd: TrieNode) -> bool:
        """Drop ``nd`` and its descendants if none are pinned.

        Returns True when pruned (handles land in ``pending_free`` and the
        expiry/version counters advance); False when a pin anywhere in the
        subtree forces deferral — the walk then simply treats the stale
        node as a miss and the subtree is pruned on a later walk.
        """
        sub, stack = [], [nd]
        while stack:
            cur = stack.pop()
            if cur.refs:
                return False
            sub.append(cur)
            stack.extend(cur.children.values())
        del nd.parent.children[nd.key]
        for cur in sub:
            self.total_bytes -= cur.nbytes
            self.n_nodes -= 1
            if cur.version != self.version:
                self.stats.version_evictions += 1
            else:
                self.stats.expiries += 1
            self.pending_free.append(cur.handle)
        return True

    def _step(self, node: TrieNode, key: Hashable, now: float):
        """One walk step honoring staleness.

        Returns the live child, None when the edge is missing (or was
        stale and just pruned), or :data:`_BLOCKED` when the child is
        stale but a pin in its subtree defers pruning — the walk must
        stop there without matching, creating, or overwriting anything.
        """
        child = node.children.get(key)
        if child is None:
            return None
        if self._stale(child, now):
            return None if self._prune_subtree(child) else _BLOCKED
        return child

    def drain_pruned(self) -> list[Any]:
        """Hand back (and forget) payload handles freed by lazy pruning."""
        out, self.pending_free = self.pending_free, []
        return out

    # ------------------------------------------------------------------
    def lookup(self, chunk_keys: Sequence[Hashable],
               acquire: bool = False) -> list[TrieNode]:
        """Longest chunk-aligned prefix match.

        Returns the node path for the longest prefix of ``chunk_keys``
        present in the trie (empty list on a total miss) and bumps every
        matched node's recency and use count.  Stale nodes (TTL-expired or
        from an older weight version) never match: the walk prunes their
        subtree in place (handles go to ``pending_free``) and stops.
        ``acquire=True`` additionally pins each node on the path
        (refcount +1) so eviction cannot free a payload the caller is
        about to splice; the caller must :meth:`release` the same list
        when done.
        """
        self.stats.lookups += 1
        self.stats.lookup_chunks += len(chunk_keys)
        t = self._tick()
        now = self.clock()
        node, path = self.root, []
        for key in chunk_keys:
            child = self._step(node, key, now)
            if child is None or child is _BLOCKED:
                break
            child.last_use = t
            child.uses += 1
            path.append(child)
            node = child
        if acquire:
            for nd in path:
                nd.refs += 1
        self.stats.hit_chunks += len(path)
        if path:
            self.stats.hits += 1
        else:
            self.stats.misses += 1
        return path

    def release(self, nodes: Iterable[TrieNode]) -> None:
        """Unpin nodes previously acquired by ``lookup(acquire=True)``."""
        for nd in nodes:
            if nd.refs <= 0:
                raise ValueError("release without matching acquire")
            nd.refs -= 1

    # ------------------------------------------------------------------
    def insert(self, chunk_keys: Sequence[Hashable],
               entries: Sequence[tuple[Any, int] | None]):
        """Insert/extend one chunk path.

        ``entries[i]`` is ``(handle, nbytes)`` for chunk ``i``, or None when
        the caller expects the node to already exist (e.g. the matched
        prefix of a warm request).  Walks the path, creating nodes where
        missing; stops early if a node is missing but its entry is None.
        Returns ``(created, unused_handles, evicted_handles)``: handles the
        trie did not take ownership of (a racing insert already cached that
        chunk) plus handles freed by the post-insert eviction pass — the
        caller must free both sets in its payload store.
        """
        if len(entries) != len(chunk_keys):
            raise ValueError(f"{len(entries)} entries for {len(chunk_keys)} keys")
        t = self._tick()
        now = self.clock()
        node = self.root
        created: list[TrieNode] = []
        unused: list[Any] = []
        for i, (key, entry) in enumerate(zip(chunk_keys, entries)):
            child = self._step(node, key, now)
            if child is _BLOCKED:
                # a pinned-but-stale subtree occupies this edge: nothing
                # below it may be matched or replaced until it is pruned
                unused.extend(e[0] for e in entries[i:] if e is not None)
                break
            if child is None:
                if entry is None:
                    # cannot extend past a missing unbacked node; hand every
                    # remaining provided handle back so the caller's store
                    # does not leak the orphaned payloads
                    unused.extend(e[0] for e in entries[i:] if e is not None)
                    break
                handle, nbytes = entry
                child = TrieNode(key, node, handle, nbytes,
                                 created_at=now, version=self.version)
                node.children[key] = child
                self.total_bytes += child.nbytes
                self.n_nodes += 1
                self.stats.inserts += 1
                created.append(child)
            elif entry is not None:
                unused.append(entry[0])
            child.last_use = t
            node = child
        return created, unused, self.evict_to_budget()

    # ------------------------------------------------------------------
    def _evictable_leaves(self) -> list[TrieNode]:
        out, stack = [], list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
            elif nd.refs == 0:
                out.append(nd)
        return out

    def _victim_rank(self, nd: TrieNode) -> tuple:
        # LRU: oldest recency first.  LFU: fewest uses first, recency as
        # the tiebreak so equal-frequency victims still age out in order.
        if self.eviction == "lfu":
            return (nd.uses, nd.last_use)
        return (nd.last_use,)

    def evict_to_budget(self) -> list[Any]:
        """Evict LRU/LFU evictable leaves until within budget.

        Returns the payload handles freed (for the caller's store).  May
        leave the trie above budget when every remaining leaf is pinned —
        referenced nodes are never evicted.  One trie walk seeds a heap of
        evictable leaves; a victim's parent joins the heap the moment it
        becomes a childless unpinned leaf, so an eviction burst is
        O(nodes log nodes), not a full re-walk per victim.
        """
        evicted: list[Any] = []
        if self.total_bytes <= self.budget_bytes:
            return evicted
        heap = [(self._victim_rank(nd), id(nd), nd)
                for nd in self._evictable_leaves()]
        heapq.heapify(heap)
        while self.total_bytes > self.budget_bytes and heap:
            _, _, victim = heapq.heappop(heap)
            del victim.parent.children[victim.key]
            self.total_bytes -= victim.nbytes
            self.n_nodes -= 1
            self.stats.evictions += 1
            evicted.append(victim.handle)
            parent = victim.parent
            if (parent is not self.root and not parent.children
                    and parent.refs == 0):
                heapq.heappush(heap, (self._victim_rank(parent), id(parent),
                                      parent))
        return evicted

    def live_handles(self) -> list[Any]:
        """Every payload handle the trie currently owns (one per node).

        The engine feeds this to :meth:`PagePool.audit` as the retained
        multiset, closing the refcount accounting loop: a page is live iff
        it is in a block table or behind one of these handles.  Handles in
        ``pending_free`` are NOT included — they are already disowned and
        waiting for the facade to free them in the store.
        """
        out, stack = [], list(self.root.children.values())
        while stack:
            nd = stack.pop()
            out.append(nd.handle)
            stack.extend(nd.children.values())
        return out

    def audit(self) -> dict:
        """Structural invariant audit; returns a report, never raises.

        Recounts nodes and bytes against the incremental counters, checks
        parent/child back-pointers, and flags negative refcounts.  Cheap
        (one walk), so chaos tests run it after every schedule.
        """
        issues: list[str] = []
        n, nbytes = 0, 0
        stack = [self.root]
        while stack:
            nd = stack.pop()
            for key, child in nd.children.items():
                if child.parent is not nd:
                    issues.append(f"node {key!r}: broken parent pointer")
                if child.key != key:
                    issues.append(f"node {key!r}: edge/key mismatch {child.key!r}")
                if child.refs < 0:
                    issues.append(f"node {key!r}: negative refcount {child.refs}")
                n += 1
                nbytes += child.nbytes
                stack.append(child)
        if n != self.n_nodes:
            issues.append(f"n_nodes counter {self.n_nodes} != walked {n}")
        if nbytes != self.total_bytes:
            issues.append(f"total_bytes counter {self.total_bytes} != walked {nbytes}")
        return {"ok": not issues, "issues": issues, "n_nodes": n,
                "total_bytes": nbytes, "pending_free": len(self.pending_free)}

    def clear(self) -> list[Any]:
        """Drop every node (ignores pins — callers must hold none).
        Returns all payload handles for the caller's store."""
        handles = []
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            handles.append(nd.handle)
            stack.extend(nd.children.values())
        self.root.children.clear()
        self.total_bytes = 0
        self.n_nodes = 0
        return handles
