"""Pallas TPU kernel: fused per-channel quantize + bit-pack.

One compression event (a streaming-buffer chunk) per grid step: the chunk
tile lives in VMEM, min/max reductions run on the VPU, the quantize +
shift/or pack is fully vectorized, and packed int32 lanes + scale/zero are
written back without ever materializing int codes in HBM — the fusion the
paper implements in CUDA for the quantization path.

Layout matches :func:`repro.kernels.ref.quant_pack_ref`:
  x [N, n, d]  ->  packed int32 [N, n, d//per], scale/zero f32 [N, d]
  (per = 32 // bits; groups = whole columns of the chunk)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_pack"]


def _kernel(x_ref, packed_ref, scale_ref, zero_ref, *, bits: int):
    x = x_ref[0].astype(jnp.float32)            # [n, d]
    n, d = x.shape
    per = 32 // bits
    mn = jnp.min(x, axis=0)                      # [d]
    mx = jnp.max(x, axis=0)
    scale = jnp.maximum((mx - mn) / (2**bits - 1), 1e-8)
    codes = jnp.clip(jnp.round((x - mn[None, :]) / scale[None, :]),
                     0, 2**bits - 1).astype(jnp.uint32)
    lanes = codes.reshape(n, d // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, None, :]
    packed = jnp.sum(lanes << shifts, axis=-1, dtype=jnp.uint32)
    packed_ref[0] = packed.astype(jnp.int32)
    scale_ref[0] = scale
    zero_ref[0] = mn


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quant_pack(x: jnp.ndarray, bits: int, interpret: bool = False):
    """x: [N, n, d] -> (packed [N, n, d//per] int32, scale [N, d], zero [N, d])."""
    N, n, d = x.shape
    per = 32 // bits
    grid = (N,)
    out_shapes = (
        jax.ShapeDtypeStruct((N, n, d // per), jnp.int32),
        jax.ShapeDtypeStruct((N, d), jnp.float32),
        jax.ShapeDtypeStruct((N, d), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((1, n, d), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((1, n, d // per), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x)
