"""Pallas TPU kernels for the GEAR hot path (validated via interpret=True).

gear_decode   — fused dequant + sparse-scatter + low-rank + online-softmax
                decode attention over the compressed cache (the paper's
                fused CUDA dequant-GEMM, TPU-native).
quant_pack    — fused per-channel quantize + int32 bit-pack (compression step).
flash_prefill — blocked causal/window/prefix attention for prefill.
ops           — jit'd dispatch wrappers (kernel on TPU, jnp oracle elsewhere).
ref           — pure-jnp oracles defining each kernel's contract.
"""
from repro.kernels.ops import gear_attend, flash_attention, quantize_chunk, on_tpu
