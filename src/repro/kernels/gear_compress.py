"""Pallas TPU kernel: fused GEAR chunk compression.

One compression event per grid step, entirely VMEM-resident — the
device-side analogue of the paper's fused CUDA compression path that
KVComp/PackKV show is where the peak-memory/throughput win comes from.
Consumed by streaming chunked prefill
(:func:`repro.core.cache.streaming_prefill_pipeline` via the ``fused``
knob); decode's buffer-close event still runs the plain XLA
``compress_matrix`` path (wiring it through ``append_token`` is future
work).  Per chunk tile ``[n_b, Dh]`` the kernel:

  1. extracts the top/bottom ``k`` magnitude outliers per vector with
     :func:`repro.core.outlier.iterative_topk` (masked max sweeps — pure
     vector ops, :func:`jax.lax.top_k` ordering) and densifies them with
     sequential compare-iota selects (set semantics, matching the oracle's
     scatter),
  2. quantizes the remainder with the chunk-local uniform asymmetric
     quantizer (per-channel token groups for K, per-token channel groups for
     V — both orientations of :mod:`repro.core.quant`),
  3. packs the codes into int32 lanes with vectorized shift/or
     (:mod:`repro.core.packing` layout),
  4. emits the quantization residual ``(x − S) − deq(D̂)`` in f32 for the
     XLA-side power-iteration low-rank step (stats are rounded through the
     cache's storage dtype first so the residual matches what
     :func:`repro.core.gear.compress_matrix` would hand the SVD solver).

Int codes, min/max stats, and the outlier scratch never touch HBM; the HBM
traffic of one compression event is exactly its compressed output plus one
chunk of input/residual.

Layout contract (shared with :func:`repro.kernels.ref.gear_compress_ref`):

  x [N, n_b, Dh]  ->  packed   int32 [N, n_b, Dh // (32/bits)]
                      scale/zero f32 [N, n_b/g, Dh]   (per_channel, g tokens)
                                     [N, n_b, Dh/g]   (per_token*, g channels)
                      sp_val/idx     [N, Dh, 2k]      (per_channel: token idx)
                                     [N, n_b, 2k]     (per_token*: channel idx)
                      resid      f32 [N, n_b, Dh]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.outlier import iterative_topk

__all__ = ["gear_compress"]


def _kernel(x_ref, *refs, bits: int, group: int, per_channel: bool,
            n_out: int, stat_dtype: str):
    if n_out:
        packed_ref, scale_ref, zero_ref, spv_ref, spi_ref, resid_ref = refs
    else:
        packed_ref, scale_ref, zero_ref, resid_ref = refs
    x = x_ref[0].astype(jnp.float32)                     # [nb, d]
    nb, d = x.shape
    per = 32 // bits

    # ---- outliers: top/bottom k per vector, densified via select chain ----
    r = x
    if n_out:
        axis = 0 if per_channel else 1
        top_v, top_i = iterative_topk(x, n_out, axis=axis)
        bot_v, bot_i = iterative_topk(-x, n_out, axis=axis)
        iota = jax.lax.broadcasted_iota(jnp.int32, (nb, d), axis)
        dense = jnp.zeros((nb, d), jnp.float32)
        # sequential selects = the oracle's scatter-set (top first, then
        # bottom; a position in both sets carries the same value either way)
        for j in range(n_out):
            dense = jnp.where(iota == jnp.expand_dims(top_i[:, j], axis),
                              jnp.expand_dims(top_v[:, j], axis), dense)
        for j in range(n_out):
            dense = jnp.where(iota == jnp.expand_dims(bot_i[:, j], axis),
                              jnp.expand_dims(-bot_v[:, j], axis), dense)
        r = x - dense
        spv_ref[0] = jnp.concatenate([top_v, -bot_v], axis=-1)
        spi_ref[0] = jnp.concatenate([top_i, bot_i], axis=-1)

    # ---- quantize the remainder (chunk-local groups) ----------------------
    if per_channel:                                      # groups of g tokens
        rg = r.reshape(nb // group, group, d)
        mn = jnp.min(rg, axis=1)                         # [nb/g, d]
        mx = jnp.max(rg, axis=1)
        scale = jnp.maximum((mx - mn) / (2**bits - 1), 1e-8)
        codes = jnp.clip(jnp.round((rg - mn[:, None, :]) / scale[:, None, :]),
                         0, 2**bits - 1).reshape(nb, d)
    else:                                                # groups of g channels
        rg = r.reshape(nb, d // group, group)
        mn = jnp.min(rg, axis=2)                         # [nb, d/g]
        mx = jnp.max(rg, axis=2)
        scale = jnp.maximum((mx - mn) / (2**bits - 1), 1e-8)
        codes = jnp.clip(jnp.round((rg - mn[:, :, None]) / scale[:, :, None]),
                         0, 2**bits - 1).reshape(nb, d)

    # ---- pack into int32 lanes -------------------------------------------
    lanes = codes.astype(jnp.uint32).reshape(nb, d // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, None, :]
    packed_ref[0] = jnp.sum(lanes << shifts, axis=-1,
                            dtype=jnp.uint32).astype(jnp.int32)
    scale_ref[0] = scale
    zero_ref[0] = mn

    # ---- residual for the low-rank step ----------------------------------
    # deq uses the stats as the cache will store them (bf16 by default), so
    # the residual — hence the power-iteration factors — matches the oracle.
    sd = jnp.dtype(stat_dtype)
    s_r = scale.astype(sd).astype(jnp.float32)
    z_r = mn.astype(sd).astype(jnp.float32)
    if per_channel:
        deq = (codes.reshape(nb // group, group, d) * s_r[:, None, :]
               + z_r[:, None, :]).reshape(nb, d)
    else:
        deq = (codes.reshape(nb, d // group, group) * s_r[:, :, None]
               + z_r[:, :, None]).reshape(nb, d)
    resid_ref[0] = r - deq


@functools.partial(
    jax.jit,
    static_argnames=("bits", "scheme", "group", "n_out", "stat_dtype",
                     "interpret"),
)
def gear_compress(x: jnp.ndarray, *, bits: int, scheme: str,
                  group: int | None = None, n_out: int = 0,
                  stat_dtype: str = "bfloat16", interpret: bool = False):
    """Fused quantize+pack+stats+outlier compression of a chunk batch.

    x: [N, nb, d].  ``scheme`` is a :mod:`repro.core.quant` scheme name
    (``per_channel`` = K orientation, ``per_token``/``per_token_group`` = V
    orientation); ``group=None`` selects the coarse per-vector grouping.
    ``n_out`` is the per-extreme outlier count (0 disables the sparse path).
    Returns (packed, scale, zero, sp_val, sp_idx, resid) — sp_* are None
    when ``n_out == 0``.  See :func:`repro.kernels.ref.gear_compress_ref`
    for the oracle defining the exact contract.
    """
    N, nb, d = x.shape
    per = 32 // bits
    per_channel = scheme == "per_channel"
    if group is None:
        group = nb if per_channel else d
    rows, cols = (nb // group, d) if per_channel else (nb, d // group)
    f32 = jnp.float32
    out_shape = [
        jax.ShapeDtypeStruct((N, nb, d // per), jnp.int32),
        jax.ShapeDtypeStruct((N, rows, cols), f32),
        jax.ShapeDtypeStruct((N, rows, cols), f32),
    ]
    out_specs = [
        pl.BlockSpec((1, nb, d // per), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, rows, cols), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, rows, cols), lambda i: (i, 0, 0)),
    ]
    if n_out:
        sp_rows = d if per_channel else nb
        out_shape += [
            jax.ShapeDtypeStruct((N, sp_rows, 2 * n_out), f32),
            jax.ShapeDtypeStruct((N, sp_rows, 2 * n_out), jnp.int32),
        ]
        out_specs += [
            pl.BlockSpec((1, sp_rows, 2 * n_out), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, sp_rows, 2 * n_out), lambda i: (i, 0, 0)),
        ]
    out_shape.append(jax.ShapeDtypeStruct((N, nb, d), f32))
    out_specs.append(pl.BlockSpec((1, nb, d), lambda i: (i, 0, 0)))

    kernel = functools.partial(
        _kernel, bits=bits, group=group, per_channel=per_channel,
        n_out=n_out, stat_dtype=stat_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, nb, d), lambda i: (i, 0, 0))],
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(x)
    if n_out:
        return out
    packed, scale, zero, resid = out
    return packed, scale, zero, None, None, resid
