"""Pallas TPU kernels: blocked flash attention for prefill.

``flash_prefill`` is the classic FlashAttention-2 schedule on the TPU memory
hierarchy: grid (BH, q_blocks, kv_blocks) with the KV dimension innermost;
running max / sum-exp / accumulator live in VMEM scratch, one [Bq, Dh] tile
is written to HBM per q block.  Supports the mask family the assigned archs
need: causal, sliding window (gemma3 locals), and bidirectional prefix
(paligemma).

``flash_prefill_block`` is the history-aware variant used by streaming
chunked prefill: one causal query-block × in-flight-KV-block tile per grid
step, returning the *unnormalized* (acc, m, l) online-softmax triple so the
caller can merge it with the compressed-history triple from
:func:`repro.kernels.gear_decode.gear_decode` (two-piece online softmax —
the streaming pipeline's step (a), see DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_prefill", "flash_prefill_block"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, nk: int, scale: float, window: int,
            prefix_len: int, softcap: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # [bq, Dh]
    k = k_ref[0].astype(jnp.float32)              # [bk, Dh]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qp = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = qp >= kp
    if window:
        ok &= qp - kp < window
    if prefix_len:
        ok |= (qp < prefix_len) & (kp < prefix_len)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr[:, None] + jnp.sum(p, axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)

    @pl.when(kb == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "window", "prefix_len", "softcap",
                     "kv_repeat", "interpret"),
)
def flash_prefill(q, k, v, *, bq: int = 128, bk: int = 128, window: int = 0,
                  prefix_len: int = 0, softcap: float = 0.0,
                  kv_repeat: int = 1, interpret: bool = False):
    """q: [BHq, S, Dh]; k,v: [BHq/kv_repeat, S, Dh] -> [BHq, S, Dh].

    Causal attention.  ``kv_repeat`` maps each group of ``kv_repeat``
    consecutive query rows onto one shared K/V row via the BlockSpec index
    map (GQA: rows laid out (B, Hkv, G) query-head-major) — no broadcast
    copy of K/V ever lands in HBM.
    """
    BH, S, Dh = q.shape
    assert BH % kv_repeat == 0 and k.shape[0] == BH // kv_repeat, \
        (BH, kv_repeat, k.shape)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, scale=Dh**-0.5, window=window,
        prefix_len=prefix_len, softcap=softcap)
    kv_spec = pl.BlockSpec((1, bk, Dh), lambda x, i, j: (x // kv_repeat, j, 0))
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda x, i, j: (x, i, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda x, i, j: (x, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _block_kernel(len_ref, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, softcap: float):
    q = q_ref[0].astype(jnp.float32)              # [T, Dh]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    T = q.shape[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    ok = (ki <= qi) & (ki < len_ref[0])
    s = jnp.where(ok, s, NEG_INF)
    m = jnp.max(s, axis=-1)                       # [T]
    p = jnp.exp(s - m[:, None])
    l = jnp.sum(p, axis=-1)
    acc_ref[0] = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    m_ref[0] = jnp.broadcast_to(m[:, None], m_ref[0].shape)
    l_ref[0] = jnp.broadcast_to(l[:, None], l_ref[0].shape)


@functools.partial(
    jax.jit, static_argnames=("scale", "softcap", "interpret"))
def flash_prefill_block(q, k, v, kv_len, *, scale: float, softcap: float = 0.0,
                        interpret: bool = False):
    """Causal attention of one in-flight block against itself, unnormalized.

    q, k, v: [N, T, Dh]; kv_len: [N] int32 — query row t of program n sees
    keys j with ``j <= t`` and ``j < kv_len[n]`` (partial tail chunks mask
    their padding).  Returns (acc [N, T, Dh] f32, m [N, T, 128], l
    [N, T, 128]) in the same unnormalized convention as ``gear_decode`` so
    the two triples merge with one softmax rescale.  Oracle:
    :func:`repro.kernels.ref.flash_block_ref`.
    """
    N, T, Dh = q.shape
    f32 = jnp.float32
    kernel = functools.partial(_block_kernel, scale=scale, softcap=softcap)
    n = lambda i: (i, 0, 0)
    return pl.pallas_call(
        kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, T, Dh), n),
            pl.BlockSpec((1, T, Dh), n),
            pl.BlockSpec((1, T, Dh), n),
        ],
        out_specs=(
            pl.BlockSpec((1, T, Dh), n),
            pl.BlockSpec((1, T, 128), n),
            pl.BlockSpec((1, T, 128), n),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((N, T, Dh), f32),
            jax.ShapeDtypeStruct((N, T, 128), f32),
            jax.ShapeDtypeStruct((N, T, 128), f32),
        ),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32), q, k, v)
