"""Pallas TPU kernel: blocked causal/windowed/prefix flash attention (prefill).

Classic FlashAttention-2 schedule on the TPU memory hierarchy: grid
(BH, q_blocks, kv_blocks) with the KV dimension innermost; running max /
sum-exp / accumulator live in VMEM scratch, one [Bq, Dh] tile is written to
HBM per q block.  Supports the mask family the assigned archs need: causal,
sliding window (gemma3 locals), and bidirectional prefix (paligemma).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_prefill"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, nk: int, scale: float, window: int,
            prefix_len: int, softcap: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # [bq, Dh]
    k = k_ref[0].astype(jnp.float32)              # [bk, Dh]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qp = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = qp >= kp
    if window:
        ok &= qp - kp < window
    if prefix_len:
        ok |= (qp < prefix_len) & (kp < prefix_len)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr[:, None] + jnp.sum(p, axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)

    @pl.when(kb == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "window", "prefix_len", "softcap", "interpret"),
)
def flash_prefill(q, k, v, *, bq: int = 128, bk: int = 128, window: int = 0,
                  prefix_len: int = 0, softcap: float = 0.0,
                  interpret: bool = False):
    """q,k,v: [BH, S, Dh] -> [BH, S, Dh] causal attention."""
    BH, S, Dh = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, scale=Dh**-0.5, window=window,
        prefix_len=prefix_len, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda x, i, j: (x, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda x, i, j: (x, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda x, i, j: (x, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda x, i, j: (x, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
