"""Pallas TPU kernel: chunked linear-recurrence scan (RWKV6 / Mamba-2 SSD).

The training hot path of the attention-free archs (rwkv6-3b, hymba-1.5b's
SSM heads).  Grid is (BH, chunks) with the chunk dim innermost and the
per-head state carried in VMEM scratch across grid steps — the sequential
dependency never leaves VMEM, while the intra-chunk work is three
MXU matmuls on [W, Dk]×[W, Dv] tiles (the same GLA-style factorization as
:func:`repro.models.linear_scan.chunked_scan`, which is the oracle).

Computes, per head, with decay w_t ∈ (0, 1]:
    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ
    y_t = r_tᵀ·S_t                           (mode="inclusive", Mamba)
    y_t = r_tᵀ·(S_{t-1} + diag(u) k_t v_tᵀ)  (mode="bonus", RWKV6)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["linear_scan_chunked"]

CLAMP = 30.0


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_out_ref, state_scr,
            *, chunk: int, n_chunks: int, mode: str):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    f32 = jnp.float32
    r = r_ref[0].astype(f32)            # [W, Dk]
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)            # [W, Dv]
    lw = lw_ref[0].astype(f32)          # [W, Dk]
    W, Dk = r.shape

    cum = jnp.cumsum(lw, axis=0)
    q_cum = cum if mode == "inclusive" else cum - lw
    tri = jnp.tril(jnp.ones((W, W), f32), 0 if mode == "inclusive" else -1)

    q_fac = r * jnp.exp(jnp.maximum(q_cum, -CLAMP))
    k_fac = k * jnp.exp(jnp.minimum(-cum, CLAMP))
    att = jax.lax.dot_general(q_fac, k_fac, (((1,), (1,)), ((), ())),
                              preferred_element_type=f32) * tri
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
    if mode == "bonus":
        u = u_ref[0].astype(f32)        # [1, Dk] replicated row
        bonus = jnp.sum(r * u * k, axis=-1, keepdims=True)
        y = y + bonus * v

    # cross-chunk via carried state
    state = state_scr[...]
    y = y + jax.lax.dot_general(q_fac, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=f32)
    decay_last = jnp.exp(jnp.maximum(cum[-1:, :], -CLAMP))        # [1, Dk]
    k_state = k * jnp.exp(jnp.maximum(cum[-1:, :] - cum, -CLAMP))  # [W, Dk]
    state_scr[...] = state * decay_last.T + jax.lax.dot_general(
        k_state, v, (((0,), (0,)), ((), ())), preferred_element_type=f32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _final():
        state_out_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "mode", "interpret"))
def linear_scan_chunked(r, k, v, log_w, u=None, *, chunk: int = 64,
                        mode: str = "inclusive", interpret: bool = False):
    """r,k: [BH, S, Dk]; v: [BH, S, Dv]; log_w broadcastable to r.

    Returns (y [BH, S, Dv], state [BH, Dk, Dv]).  Oracle:
    repro.models.linear_scan.chunked_scan (leading dims flattened).
    """
    BH, S, Dk = r.shape
    Dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    C = S // chunk
    lw = jnp.broadcast_to(log_w, r.shape).astype(jnp.float32)
    if u is None:
        u = jnp.zeros((BH, Dk), jnp.float32)
    u2 = u.reshape(BH, 1, Dk)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=C, mode=mode)
    y, state = pl.pallas_call(
        kernel,
        grid=(BH, C),
        in_specs=[
            pl.BlockSpec((1, chunk, Dk), lambda x, c: (x, c, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda x, c: (x, c, 0)),
            pl.BlockSpec((1, chunk, Dv), lambda x, c: (x, c, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda x, c: (x, c, 0)),
            pl.BlockSpec((1, 1, Dk), lambda x, c: (x, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, Dv), lambda x, c: (x, c, 0)),
            pl.BlockSpec((1, Dk, Dv), lambda x, c: (x, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((BH, S, Dv), v.dtype),
            jax.ShapeDtypeStruct((BH, Dk, Dv), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u2)
    return y, state
