"""Pure-jnp oracles for every Pallas kernel (same contracts, no tiling).

These are the correctness ground truth for the kernel tests and the
portable fallback used on CPU/GPU backends.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import outlier as ol
from repro.core import packing
from repro.core import quant as q_lib

__all__ = ["quant_pack_ref", "gear_decode_ref", "gear_decode_paged_ref",
           "gear_hist_block_ref", "flash_prefill_ref", "gear_compress_ref",
           "flash_block_ref", "gather_paged_operands"]

NEG_INF = -1e30


def quant_pack_ref(x: jnp.ndarray, bits: int):
    """Per-column (channel) asymmetric quantize + pack.

    x: [N, n, d] -> (packed int32 [N, n, d*bits/32], scale [N, d], zero [N, d]).
    Groups are whole columns (rows reduced) — the KCVT/chunked layout.
    """
    xf = x.astype(jnp.float32)
    mn = jnp.min(xf, axis=1)
    mx = jnp.max(xf, axis=1)
    scale = jnp.maximum((mx - mn) / (2**bits - 1), 1e-8)
    codes = jnp.clip(jnp.round((xf - mn[:, None, :]) / scale[:, None, :]),
                     0, 2**bits - 1).astype(jnp.int32)
    return packing.pack(codes, bits), scale, mn


def _dequant(packed, scale_full, zero_full, bits, d):
    codes = packing.unpack(packed, bits, d).astype(jnp.float32)
    return codes * scale_full + zero_full


def gear_decode_ref(
    q: jnp.ndarray,          # [BH, G, Dh]
    k_packed: jnp.ndarray,   # [BH, S, L] int32
    k_scale: jnp.ndarray,    # [BH, C, Dh]
    k_zero: jnp.ndarray,
    v_packed: jnp.ndarray,   # [BH, S, L]
    v_scale: jnp.ndarray,    # [BH, S, Gv]
    v_zero: jnp.ndarray,
    n_comp: jnp.ndarray,     # [] or [BH] int32 — valid compressed tokens
    *,
    bits: int,
    chunk: int,
    scale_factor: float,
    k_a=None, k_b=None,      # [BH, S, r] / [BH, C, Dh, r]
    v_a=None, v_b=None,
    k_sp_val=None, k_sp_idx=None,   # [BH, C, Dh, Ks]
    v_sp_val=None, v_sp_idx=None,   # [BH, S, Kv]
):
    """Unnormalized online-softmax decode attention over a GEAR cache.

    ``n_comp`` may be a scalar (uniform extent) or a per-row ``[BH]`` vector
    (ragged continuous batches): scores past each row's own extent are
    masked, so every output row depends only on its own slot's cache.
    Returns (acc [BH, G, Dh] f32 exp-weighted V sum, m [BH, G] score max,
    l [BH, G] sum of exp) so the caller can merge the fp16 buffer region.
    """
    BH, S, L = k_packed.shape
    Dh = k_scale.shape[-1]
    C = S // chunk
    f32 = jnp.float32

    sc = jnp.repeat(k_scale.astype(f32), chunk, axis=1)
    zr = jnp.repeat(k_zero.astype(f32), chunk, axis=1)
    k_hat = _dequant(k_packed, sc, zr, bits, Dh)                 # [BH, S, Dh]
    if k_sp_val is not None:
        oh = (k_sp_idx[..., None] == jnp.arange(chunk)).astype(f32)  # [BH,C,Dh,Ks,nb]
        k_hat = k_hat + jnp.einsum("xcdk,xcdkn->xcnd", k_sp_val.astype(f32), oh
                                   ).reshape(BH, S, Dh)
    s = jnp.einsum("xgd,xsd->xgs", q.astype(f32), k_hat)
    if k_a is not None:
        qb = jnp.einsum("xgd,xcdr->xgcr", q.astype(f32), k_b.astype(f32))
        a_c = k_a.astype(f32).reshape(BH, C, chunk, -1)
        s = s + jnp.einsum("xgcr,xcnr->xgcn", qb, a_c).reshape(BH, -1, S)
    s = s * scale_factor
    n_comp = jnp.broadcast_to(jnp.asarray(n_comp, jnp.int32), (BH,))
    valid = jnp.arange(S)[None, :] < n_comp[:, None]           # [BH, S]
    s = jnp.where(valid[:, None, :], s, NEG_INF)

    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)

    gv = v_scale.shape[-1]
    vsc = jnp.repeat(v_scale.astype(f32), Dh // gv, axis=-1)
    vzr = jnp.repeat(v_zero.astype(f32), Dh // gv, axis=-1)
    v_hat = _dequant(v_packed, vsc, vzr, bits, Dh)
    if v_sp_val is not None:
        oh = (v_sp_idx[..., None] == jnp.arange(Dh)).astype(f32)
        v_hat = v_hat + jnp.einsum("xsk,xskd->xsd", v_sp_val.astype(f32), oh)
    acc = jnp.einsum("xgs,xsd->xgd", p, v_hat)
    if v_a is not None:
        pa = jnp.einsum("xgcn,xcnr->xgcr", p.reshape(BH, -1, C, chunk),
                        v_a.astype(f32).reshape(BH, C, chunk, -1))
        acc = acc + jnp.einsum("xgcr,xcdr->xgd", pa, v_b.astype(f32))
    return acc, m, l


def gear_decode_paged_ref(
    q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero, n_comp,
    block_tables, *,
    bits: int, chunk: int, scale_factor: float,
    k_a=None, k_b=None, v_a=None, v_b=None,
    k_sp_val=None, k_sp_idx=None, v_sp_val=None, v_sp_idx=None,
):
    """Oracle for :func:`repro.kernels.gear_decode.gear_decode_paged` and
    the portable CPU/GPU paged-decode fallback.

    Takes the *same* operands as the paged kernel — head-flattened pool
    pages ``[P*H, ...one-chunk]`` plus ``block_tables [B, C]`` — gathers
    them back to the dense row layout (page ``bt[b, c]``, head ``h`` →
    row ``bt[b, c]*H + h``), and defers to :func:`gear_decode_ref`.  Under
    the pool's zero-page invariant the gathered operands are bitwise equal
    to the dense cache's, so this oracle is exact, not approximate.
    """
    BH = q.shape[0]
    g = gather_paged_operands(
        block_tables, BH,
        dict(k_packed=k_packed, k_scale=k_scale, k_zero=k_zero,
             v_packed=v_packed, v_scale=v_scale, v_zero=v_zero,
             k_a=k_a, k_b=k_b, v_a=v_a, v_b=v_b,
             k_sp_val=k_sp_val, k_sp_idx=k_sp_idx,
             v_sp_val=v_sp_val, v_sp_idx=v_sp_idx))
    return gear_decode_ref(
        q, g["k_packed"], g["k_scale"], g["k_zero"],
        g["v_packed"], g["v_scale"], g["v_zero"], n_comp,
        bits=bits, chunk=chunk, scale_factor=scale_factor,
        k_a=g["k_a"], k_b=g["k_b"], v_a=g["v_a"], v_b=g["v_b"],
        k_sp_val=g["k_sp_val"], k_sp_idx=g["k_sp_idx"],
        v_sp_val=g["v_sp_val"], v_sp_idx=g["v_sp_idx"])


def gather_paged_operands(block_tables, BH: int, pools: dict) -> dict:
    """Gather head-flattened pool operands ``[P*H, pg0, ...]`` back to the
    dense ``[BH, C*pg0, ...]`` row layout through ``block_tables [B, C]``
    (None leaves pass through).  Shared by the paged oracles and the
    portable paged-history path of ``gear_attend_block``."""
    bt = jnp.asarray(block_tables, jnp.int32)
    B, C = bt.shape
    H = BH // B
    # [B, H, C] flat pool rows, flattened to [BH, C] in bh-major order
    rows = (bt[:, None, :] * H + jnp.arange(H)[None, :, None]).reshape(BH, C)

    def gather(pool):
        if pool is None:
            return None
        g = pool[rows]                               # [BH, C, pg0, ...]
        return g.reshape((BH, C * g.shape[2]) + g.shape[3:])

    return {name: gather(pool) for name, pool in pools.items()}


def flash_prefill_ref(q, k, v, positions, *, causal: bool = True,
                      window: int = 0, prefix_len: int = 0,
                      softcap: float = 0.0):
    """Blocked-attention oracle.  q,k,v: [BH, S, Dh] -> [BH, S, Dh]."""
    f32 = jnp.float32
    Dh = q.shape[-1]
    s = jnp.einsum("xqd,xkd->xqk", q.astype(f32), k.astype(f32)) * Dh**-0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp, kp = positions, positions
    ok = jnp.ones(s.shape[-2:], bool)
    if causal:
        ok = qp[:, None] >= kp[None, :]
    if window:
        ok = ok & (qp[:, None] - kp[None, :] < window)
    if prefix_len:
        ok = ok | ((qp[:, None] < prefix_len) & (kp[None, :] < prefix_len))
    s = jnp.where(ok[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("xqk,xkd->xqd", w, v.astype(f32)).astype(q.dtype)


def gear_compress_ref(x: jnp.ndarray, *, bits: int, scheme: str,
                      group: int | None = None, n_out: int = 0,
                      stat_dtype: str = "bfloat16"):
    """Oracle for :func:`repro.kernels.gear_compress.gear_compress`.

    Built directly on :mod:`repro.core.quant` / :mod:`repro.core.outlier`,
    so its outputs are bit-identical to the corresponding pieces of
    :func:`repro.core.gear.compress_matrix` — this is both the kernel's
    ground truth and the portable CPU/GPU fallback of the fused compression
    path.  x: [N, nb, d] -> (packed, scale, zero, sp_val, sp_idx, resid);
    sp_* are None when ``n_out == 0``; scale/zero are the *unrounded* f32
    compact stats while ``resid`` is computed against stats rounded through
    ``stat_dtype`` (what the cache stores — what the SVD solver must see).
    """
    per_channel = scheme == "per_channel"
    sp_val = sp_idx = None
    remainder = x
    dense = 0.0
    if n_out:
        sp, remainder = ol.filter_outliers_k(x, n_out, "token" if per_channel
                                             else "channel")
        sp_val, sp_idx = sp.values.astype(jnp.float32), sp.indices
        dense = ol.densify(sp)
    qt = q_lib.quantize(remainder, bits, scheme, group,
                        stat_dtype=jnp.float32)
    sd = jnp.dtype(stat_dtype)
    qt_r = dataclasses.replace(qt, scale=qt.scale.astype(sd),
                               zero=qt.zero.astype(sd))
    resid = x.astype(jnp.float32) - q_lib.dequantize(qt_r) - dense
    return qt.packed, qt.scale, qt.zero, sp_val, sp_idx, resid


def flash_block_ref(q, k, v, kv_len, *, scale: float, softcap: float = 0.0):
    """Oracle for :func:`repro.kernels.flash_prefill.flash_prefill_block`.

    q,k,v: [N, T, Dh]; kv_len [N].  Returns unnormalized (acc [N, T, Dh],
    m [N, T], l [N, T]) — the caller merges with a history triple.
    """
    f32 = jnp.float32
    N, T, _ = q.shape
    s = jnp.einsum("ntd,nsd->nts", q.astype(f32), k.astype(f32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(T)[:, None]
    ki = jnp.arange(T)[None, :]
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (N,))
    ok = (ki <= qi)[None] & (ki[None] < kv_len[:, None, None])
    s = jnp.where(ok, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("nts,nsd->ntd", p, v.astype(f32))
    return acc, m, l


def gear_hist_block_ref(
    q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero, n_comp, *,
    bits: int, chunk: int, scale_factor: float,
    k_a=None, k_b=None, v_a=None, v_b=None,
    k_sp_val=None, k_sp_idx=None, v_sp_val=None, v_sp_idx=None,
):
    """Block-query twin of :func:`gear_decode_ref` tuned for the streaming-
    prefill oracle path: same contract and (f32) math, but the low-rank and
    outlier terms are densified into K̂/V̂ up front — a per-chunk A·Bᵀ GEMM
    and a vals-only scatter — so they ride the two big score/value GEMMs
    instead of paying XLA's small-einsum overhead once per scanned chunk.
    The factored forms stay in ``gear_decode`` where they belong (VMEM
    residency on TPU).  Returns (acc [BH, G, Dh], m [BH, G], l [BH, G]).
    """
    BH, S, L = k_packed.shape
    Dh = k_scale.shape[-1]
    C = S // chunk
    f32 = jnp.float32
    qf = q.astype(f32)

    sc = jnp.repeat(k_scale.astype(f32), chunk, axis=1)
    zr = jnp.repeat(k_zero.astype(f32), chunk, axis=1)
    k_hat = _dequant(k_packed, sc, zr, bits, Dh)                 # [BH, S, Dh]
    if k_a is not None:
        a_c = k_a.astype(f32).reshape(BH, C, chunk, -1)
        k_hat = k_hat + jnp.einsum("xcnr,xcdr->xcnd", a_c,
                                   k_b.astype(f32)).reshape(BH, S, Dh)
    if k_sp_val is not None:
        # densify via a 2k-deep select chain (set semantics, like
        # outlier.densify) — XLA CPU scatters serialize, selects vectorize
        iota_n = jnp.arange(chunk)[None, None, None, :]
        sp = jnp.zeros((BH, C, Dh, chunk), f32)
        for j in range(k_sp_val.shape[-1]):
            sp = jnp.where(iota_n == k_sp_idx[..., j:j + 1],
                           k_sp_val[..., j:j + 1].astype(f32), sp)
        k_hat = k_hat + jnp.swapaxes(sp, 2, 3).reshape(BH, S, Dh)
    s = jnp.einsum("xgd,xsd->xgs", qf, k_hat) * scale_factor
    n_comp = jnp.broadcast_to(jnp.asarray(n_comp, jnp.int32), (BH,))
    valid = jnp.arange(S)[None, :] < n_comp[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)

    gv = v_scale.shape[-1]
    vsc = jnp.repeat(v_scale.astype(f32), Dh // gv, axis=-1)
    vzr = jnp.repeat(v_zero.astype(f32), Dh // gv, axis=-1)
    v_hat = _dequant(v_packed, vsc, vzr, bits, Dh)
    if v_a is not None:
        a_c = v_a.astype(f32).reshape(BH, C, chunk, -1)
        v_hat = v_hat + jnp.einsum("xcnr,xcdr->xcnd", a_c,
                                   v_b.astype(f32)).reshape(BH, S, Dh)
    if v_sp_val is not None:
        iota_d = jnp.arange(Dh)[None, None, :]
        sp_v = jnp.zeros((BH, S, Dh), f32)
        for j in range(v_sp_val.shape[-1]):
            sp_v = jnp.where(iota_d == v_sp_idx[..., j:j + 1],
                             v_sp_val[..., j:j + 1].astype(f32), sp_v)
        v_hat = v_hat + sp_v
    acc = jnp.einsum("xgs,xsd->xgd", p, v_hat)
    return acc, m, l
