"""Jit'd wrappers dispatching Pallas kernels (TPU) or jnp oracles (CPU/GPU).

``gear_attend`` is the drop-in high-performance replacement for
:func:`repro.core.cache.attend`: the compressed region goes through the
fused ``gear_decode`` kernel (or its oracle off-TPU), the FP16 streaming
buffer is merged with one softmax-rescale, matching the paper's streaming
design where only compressed history pays the dequantization path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cache import CacheConfig
from repro.kernels import ref as ref_ops
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.gear_decode import gear_decode
from repro.kernels.quant_pack import quant_pack

__all__ = ["on_tpu", "fused_supported", "gear_attend", "flash_attention",
           "quantize_chunk"]

NEG_INF = -1e30


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flat(x, bh):
    return None if x is None else x.reshape((bh,) + x.shape[2:])


def fused_supported(cfg: CacheConfig) -> bool:
    """True when this layer cache has the fused-kernel layout.

    The kernel streams one K-stat row per chunk, so it needs a GEAR cache
    with per-channel K quantization at chunk granularity (group == chunk);
    both recommended policies (GEAR-KCVT-4bit, GEAR-KIVI-2bit) qualify, the
    FlexGen-style per-token-group backbone (K in the V layout) does not.
    The check is static — safe to branch on at trace time.
    """
    if cfg.kind != "gear" or cfg.policy.is_fp16:
        return False
    scheme, group = cfg.k_scheme()
    if scheme != "per_channel":
        return False
    return (cfg.chunk if group is None else group) == cfg.chunk


def gear_attend(cfg: CacheConfig, cache, q: jnp.ndarray, scale: float,
                force_kernel: bool = False, interpret: bool = False) -> jnp.ndarray:
    """Decode attention over a GEAR layer cache via the fused kernel path.

    q: [B, Hq, Dh] -> [B, Hq, Dh].  Requires the engine layout
    (group == chunk for K — :func:`fused_supported`; see DESIGN.md) which
    both recommended policies (GEAR-KCVT-4bit, GEAR-KIVI-2bit) satisfy.

    Ragged-aware: ``cache.length`` is the per-slot ``[B]`` length vector and
    every slot attends over exactly its own compressed extent and buffer
    fill, inside the kernel — mixed-length continuous batches take this
    path directly (DESIGN.md §ragged fused decode).
    """
    pol = cfg.policy
    B, Hq, Dh = q.shape
    H = cfg.kv_heads
    G = Hq // H
    BH = B * H
    qf = q.astype(jnp.float32).reshape(BH, G, Dh)
    nb = cfg.chunk
    # per-slot extents, repeated per head to match the [B*H] kernel rows
    length = jnp.broadcast_to(jnp.asarray(cache.length, jnp.int32), (B,))
    len_bh = jnp.repeat(length, H)            # [BH]
    n_comp = (len_bh // nb) * nb              # [BH] compressed extent per row
    n_buf = len_bh - n_comp                   # [BH] streaming-buffer fill

    kwargs = dict(bits=pol.bits, chunk=nb, scale_factor=scale)
    lr = dict(
        k_a=_flat(cache.k_a, BH), k_b=_flat(cache.k_b, BH),
        v_a=_flat(cache.v_a, BH), v_b=_flat(cache.v_b, BH),
    ) if pol.use_lowrank else {}
    sp = dict(
        k_sp_val=_flat(cache.k_sp_val, BH), k_sp_idx=_flat(cache.k_sp_idx, BH),
        v_sp_val=_flat(cache.v_sp_val, BH), v_sp_idx=_flat(cache.v_sp_idx, BH),
    ) if pol.use_sparse else {}
    common = (qf, _flat(cache.k_packed, BH), _flat(cache.k_scale, BH),
              _flat(cache.k_zero, BH), _flat(cache.v_packed, BH),
              _flat(cache.v_scale, BH), _flat(cache.v_zero, BH), n_comp)
    if on_tpu() or force_kernel:
        acc, m, l = gear_decode(*common, interpret=interpret or not on_tpu(),
                                **kwargs, **lr, **sp)
        m, l = m[..., 0], l[..., 0]
    else:
        acc, m, l = ref_ops.gear_decode_ref(*common, **kwargs, **lr, **sp)

    # merge the fp16 buffer region (n_b tokens, plain XLA, per-slot masks)
    s_buf = jnp.einsum("xgd,xnd->xgn", qf,
                       _flat(cache.buf_k, BH).astype(jnp.float32)) * scale
    buf_valid = jnp.arange(nb)[None, None, :] < n_buf[:, None, None]
    s_buf = jnp.where(buf_valid, s_buf, NEG_INF)
    m_buf = jnp.max(s_buf, axis=-1)
    m_tot = jnp.maximum(m, m_buf)
    p_buf = jnp.exp(s_buf - m_tot[..., None])
    acc_buf = jnp.einsum("xgn,xnd->xgd", p_buf,
                         _flat(cache.buf_v, BH).astype(jnp.float32))
    corr = jnp.exp(m - m_tot)
    l_tot = l * corr + jnp.sum(p_buf, axis=-1)
    out = (acc * corr[..., None] + acc_buf) / jnp.maximum(l_tot[..., None], 1e-30)
    return out.reshape(B, Hq, Dh).astype(q.dtype)


def flash_attention(q, k, v, *, window: int = 0, prefix_len: int = 0,
                    softcap: float = 0.0, interpret: bool = False):
    """q,k,v: [BH, S, Dh] causal attention; kernel on TPU, oracle elsewhere."""
    if on_tpu():
        return flash_prefill(q, k, v, window=window, prefix_len=prefix_len,
                             softcap=softcap, interpret=False)
    if interpret:
        return flash_prefill(q, k, v, window=window, prefix_len=prefix_len,
                             softcap=softcap, interpret=True)
    S = q.shape[1]
    return ref_ops.flash_prefill_ref(q, k, v, jnp.arange(S), causal=True,
                                     window=window, prefix_len=prefix_len,
                                     softcap=softcap)


def quantize_chunk(x: jnp.ndarray, bits: int, interpret: bool = False):
    """Fused per-channel quantize+pack of a chunk batch [N, n, d]."""
    if on_tpu() or interpret:
        return quant_pack(x, bits, interpret=interpret or not on_tpu())
    return ref_ops.quant_pack_ref(x, bits)
