"""Jit'd wrappers dispatching Pallas kernels (TPU) or jnp oracles (CPU/GPU).

``gear_attend`` is the drop-in high-performance replacement for
:func:`repro.core.cache.attend`: the compressed region goes through the
fused ``gear_decode`` kernel (or its oracle off-TPU), the FP16 streaming
buffer is merged with one softmax-rescale, matching the paper's streaming
design where only compressed history pays the dequantization path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cache import (CacheConfig, PagedGEARLayerCache,
                              streaming_supported)
from repro.kernels import ref as ref_ops
from repro.kernels.flash_prefill import flash_prefill, flash_prefill_block
from repro.kernels.gear_compress import gear_compress
from repro.kernels.gear_decode import gear_decode, gear_decode_paged
from repro.kernels.quant_pack import quant_pack

__all__ = ["on_tpu", "fused_supported",
           "gear_attend", "gear_attend_paged", "gear_attend_block",
           "gear_compress_chunks", "flash_attention", "quantize_chunk"]

NEG_INF = -1e30


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flat(x, bh):
    return None if x is None else x.reshape((bh,) + x.shape[2:])


def fused_supported(cfg: CacheConfig) -> bool:
    """True when this layer cache has the fused-kernel layout.

    The kernel streams one K-stat row per chunk, so it needs a GEAR cache
    with per-channel K quantization at chunk granularity (group == chunk);
    both recommended policies (GEAR-KCVT-4bit, GEAR-KIVI-2bit) qualify, the
    FlexGen-style per-token-group backbone (K in the V layout) does not.
    The check is static — safe to branch on at trace time.  Streaming
    prefill's history scorer shares the layout, so this is the same
    predicate as :func:`repro.core.cache.streaming_supported`.
    """
    return streaming_supported(cfg)


def gear_compress_chunks(x: jnp.ndarray, *, bits: int, scheme: str,
                         group: int | None, n_out: int,
                         stat_dtype: str = "bfloat16",
                         force_kernel: bool = False, interpret: bool = False):
    """Fused chunk compression: Pallas kernel on TPU (or forced interpret),
    bit-exact jnp oracle elsewhere.  x: [N, nb, d] — see
    :func:`repro.kernels.ref.gear_compress_ref` for the contract."""
    if on_tpu() or force_kernel:
        return gear_compress(x, bits=bits, scheme=scheme, group=group,
                             n_out=n_out, stat_dtype=stat_dtype,
                             interpret=interpret or not on_tpu())
    return ref_ops.gear_compress_ref(x, bits=bits, scheme=scheme, group=group,
                                     n_out=n_out, stat_dtype=stat_dtype)


def _gear_operands(cfg: CacheConfig, cache, BH: int):
    """Flatten a GEAR layer cache into the [BH]-leading operand groups the
    ``gear_decode`` kernel/oracle contract takes — shared by the decode
    step (:func:`gear_attend`) and the streaming prefill block
    (:func:`gear_attend_block`), so a new cache leaf is threaded once."""
    pol = cfg.policy
    lr = dict(
        k_a=_flat(cache.k_a, BH), k_b=_flat(cache.k_b, BH),
        v_a=_flat(cache.v_a, BH), v_b=_flat(cache.v_b, BH),
    ) if pol.use_lowrank else {}
    sp = dict(
        k_sp_val=_flat(cache.k_sp_val, BH), k_sp_idx=_flat(cache.k_sp_idx, BH),
        v_sp_val=_flat(cache.v_sp_val, BH), v_sp_idx=_flat(cache.v_sp_idx, BH),
    ) if pol.use_sparse else {}
    arrays = (_flat(cache.k_packed, BH), _flat(cache.k_scale, BH),
              _flat(cache.k_zero, BH), _flat(cache.v_packed, BH),
              _flat(cache.v_scale, BH), _flat(cache.v_zero, BH))
    return arrays, lr, sp


def _pool_flat(x):
    """Pool leaf [P, H, ...] -> kernel row layout [P*H, ...] (page p, head
    h at row p*H + h — the addressing ``gear_decode_paged`` index maps and
    ``gear_decode_paged_ref`` both assume)."""
    return None if x is None else x.reshape((-1,) + x.shape[2:])


def _paged_operands(cfg: CacheConfig, pcache: PagedGEARLayerCache):
    """Paged twin of :func:`_gear_operands`: head-flattened pool pages in
    the ``gear_decode_paged`` operand order."""
    pol = cfg.policy
    lr = dict(
        k_a=_pool_flat(pcache.k_a), k_b=_pool_flat(pcache.k_b),
        v_a=_pool_flat(pcache.v_a), v_b=_pool_flat(pcache.v_b),
    ) if pol.use_lowrank else {}
    sp = dict(
        k_sp_val=_pool_flat(pcache.k_sp_val), k_sp_idx=_pool_flat(pcache.k_sp_idx),
        v_sp_val=_pool_flat(pcache.v_sp_val), v_sp_idx=_pool_flat(pcache.v_sp_idx),
    ) if pol.use_sparse else {}
    arrays = (_pool_flat(pcache.k_packed), _pool_flat(pcache.k_scale),
              _pool_flat(pcache.k_zero), _pool_flat(pcache.v_packed),
              _pool_flat(pcache.v_scale), _pool_flat(pcache.v_zero))
    return arrays, lr, sp


def _merge_buffer(cfg: CacheConfig, cache, qf, acc, m, l, n_buf, scale):
    """Merge the FP16 streaming-buffer region into a history (acc, m, l)
    triple and normalize — the XLA tail both decode paths (dense
    :func:`gear_attend`, paged :func:`gear_attend_paged`) share, so the
    merge math is one piece of code and stays bit-identical across
    layouts.  qf: [BH, G, Dh] f32; returns normalized [BH, G, Dh] f32."""
    BH = qf.shape[0]
    nb = cfg.chunk
    s_buf = jnp.einsum("xgd,xnd->xgn", qf,
                       _flat(cache.buf_k, BH).astype(jnp.float32)) * scale
    buf_valid = jnp.arange(nb)[None, None, :] < n_buf[:, None, None]
    s_buf = jnp.where(buf_valid, s_buf, NEG_INF)
    m_buf = jnp.max(s_buf, axis=-1)
    m_tot = jnp.maximum(m, m_buf)
    p_buf = jnp.exp(s_buf - m_tot[..., None])
    acc_buf = jnp.einsum("xgn,xnd->xgd", p_buf,
                         _flat(cache.buf_v, BH).astype(jnp.float32))
    corr = jnp.exp(m - m_tot)
    l_tot = l * corr + jnp.sum(p_buf, axis=-1)
    return (acc * corr[..., None] + acc_buf) / jnp.maximum(
        l_tot[..., None], 1e-30)


def gear_attend_block(cfg: CacheConfig, cache, q: jnp.ndarray,
                      k_blk: jnp.ndarray, v_blk: jnp.ndarray,
                      n_comp, blk_len, scale: float,
                      force_kernel: bool = False,
                      interpret: bool = False,
                      force_oracle: bool = False,
                      block_tables: jnp.ndarray | None = None) -> jnp.ndarray:
    """Streaming-prefill attention of one query block: compressed history
    + in-flight FP16 block, merged with a two-piece online softmax.

    q: [B, Hq, T, Dh] (the current chunk's queries); k_blk/v_blk:
    [B, H, T, Dh] (the same chunk's uncompressed K/V); ``n_comp`` — scalar
    compressed extent (tokens in chunks already closed, i.e. ``c · n_b``);
    ``blk_len`` — valid tokens in the block (< T only for the tail).
    History scores run the ``gear_decode`` machinery (kernel on TPU, oracle
    elsewhere; ``force_oracle`` pins the jnp oracles even on TPU — the
    ``fused="off"`` escape hatch) with the chunk's T·G query rows sharing
    one extent mask; the block piece is ``flash_prefill_block`` with causal
    masking.  Returns [B, Hq, T, Dh] in q's dtype.

    A :class:`~repro.core.cache.PagedGEARLayerCache` history (pool pages +
    ``block_tables [B, C]``) takes the same contract: the fused path runs
    :func:`gear_decode_paged`, the oracle path gathers the pool rows and
    runs the identical dense history math.
    """
    pol = cfg.policy
    B, Hq, T, Dh = q.shape
    H = cfg.kv_heads
    G = Hq // H
    BH = B * H
    nb = cfg.chunk
    f32 = jnp.float32
    qf = q.astype(f32).reshape(B, H, G, T, Dh)
    use_kernel = (on_tpu() or force_kernel) and not force_oracle
    run_interp = interpret or not on_tpu()
    paged = isinstance(cache, PagedGEARLayerCache)
    if paged and block_tables is None:
        raise ValueError("paged history needs block_tables")

    # --- compressed history: unnormalized (acc, m, l) over T·G query rows --
    kwargs = dict(bits=pol.bits, chunk=nb, scale_factor=scale)
    if paged:
        arrays, lr, sp = _paged_operands(cfg, cache)
    else:
        arrays, lr, sp = _gear_operands(cfg, cache, BH)
    n_comp_bh = jnp.broadcast_to(jnp.asarray(n_comp, jnp.int32), (BH,))
    q_rows = qf.reshape(BH, G * T, Dh)
    common = (q_rows, *arrays, n_comp_bh)
    if use_kernel:
        if paged:
            acc_h, m_h, l_h = gear_decode_paged(
                *common, jnp.asarray(block_tables, jnp.int32),
                interpret=run_interp, **kwargs, **lr, **sp)
        else:
            acc_h, m_h, l_h = gear_decode(*common, interpret=run_interp,
                                          **kwargs, **lr, **sp)
        m_h, l_h = m_h[..., 0], l_h[..., 0]
    else:
        if paged:
            names = ("k_packed", "k_scale", "k_zero",
                     "v_packed", "v_scale", "v_zero")
            g = ref_ops.gather_paged_operands(
                block_tables, BH, dict(zip(names, arrays)) | lr | sp)
            arrays = tuple(g[n] for n in names)
            lr = {n: g[n] for n in lr}
            sp = {n: g[n] for n in sp}
            common = (q_rows, *arrays, n_comp_bh)
        acc_h, m_h, l_h = ref_ops.gear_hist_block_ref(*common, **kwargs,
                                                      **lr, **sp)
    acc_h = acc_h.reshape(B, H, G, T, Dh)
    m_h = m_h.reshape(B, H, G, T)
    l_h = l_h.reshape(B, H, G, T)

    # --- in-flight FP16 block: causal within the chunk ---------------------
    N2 = BH * G
    q_blk = qf.reshape(N2, T, Dh)
    k3 = jnp.broadcast_to(k_blk.astype(f32)[:, :, None], (B, H, G, T, Dh))
    v3 = jnp.broadcast_to(v_blk.astype(f32)[:, :, None], (B, H, G, T, Dh))
    kv_len = jnp.broadcast_to(jnp.asarray(blk_len, jnp.int32), (N2,))
    if use_kernel:
        acc_b, m_b, l_b = flash_prefill_block(
            q_blk, k3.reshape(N2, T, Dh), v3.reshape(N2, T, Dh), kv_len,
            scale=scale, interpret=run_interp)
        m_b, l_b = m_b[..., 0], l_b[..., 0]
    else:
        acc_b, m_b, l_b = ref_ops.flash_block_ref(
            q_blk, k3.reshape(N2, T, Dh), v3.reshape(N2, T, Dh), kv_len,
            scale=scale)
    acc_b = acc_b.reshape(B, H, G, T, Dh)
    m_b = m_b.reshape(B, H, G, T)
    l_b = l_b.reshape(B, H, G, T)

    # --- two-piece merge + normalize ---------------------------------------
    m_tot = jnp.maximum(m_h, m_b)
    c_h = jnp.exp(m_h - m_tot)
    c_b = jnp.exp(m_b - m_tot)
    l_tot = l_h * c_h + l_b * c_b
    out = (acc_h * c_h[..., None] + acc_b * c_b[..., None]) / jnp.maximum(
        l_tot[..., None], 1e-30)
    return out.reshape(B, Hq, T, Dh).astype(q.dtype)


def gear_attend(cfg: CacheConfig, cache, q: jnp.ndarray, scale: float,
                force_kernel: bool = False, interpret: bool = False) -> jnp.ndarray:
    """Decode attention over a GEAR layer cache via the fused kernel path.

    q: [B, Hq, Dh] -> [B, Hq, Dh].  Requires the engine layout
    (group == chunk for K — :func:`fused_supported`; see DESIGN.md) which
    both recommended policies (GEAR-KCVT-4bit, GEAR-KIVI-2bit) satisfy.

    Ragged-aware: ``cache.length`` is the per-slot ``[B]`` length vector and
    every slot attends over exactly its own compressed extent and buffer
    fill, inside the kernel — mixed-length continuous batches take this
    path directly (DESIGN.md §ragged fused decode).
    """
    pol = cfg.policy
    B, Hq, Dh = q.shape
    H = cfg.kv_heads
    G = Hq // H
    BH = B * H
    qf = q.astype(jnp.float32).reshape(BH, G, Dh)
    nb = cfg.chunk
    # per-slot extents, repeated per head to match the [B*H] kernel rows
    length = jnp.broadcast_to(jnp.asarray(cache.length, jnp.int32), (B,))
    len_bh = jnp.repeat(length, H)            # [BH]
    n_comp = (len_bh // nb) * nb              # [BH] compressed extent per row
    n_buf = len_bh - n_comp                   # [BH] streaming-buffer fill

    kwargs = dict(bits=pol.bits, chunk=nb, scale_factor=scale)
    arrays, lr, sp = _gear_operands(cfg, cache, BH)
    common = (qf, *arrays, n_comp)
    if on_tpu() or force_kernel:
        acc, m, l = gear_decode(*common, interpret=interpret or not on_tpu(),
                                **kwargs, **lr, **sp)
        m, l = m[..., 0], l[..., 0]
    else:
        acc, m, l = ref_ops.gear_decode_ref(*common, **kwargs, **lr, **sp)

    # merge the fp16 buffer region (n_b tokens, plain XLA, per-slot masks)
    out = _merge_buffer(cfg, cache, qf, acc, m, l, n_buf, scale)
    return out.reshape(B, Hq, Dh).astype(q.dtype)


def gear_attend_paged(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                      block_tables: jnp.ndarray, q: jnp.ndarray,
                      scale: float, force_kernel: bool = False,
                      interpret: bool = False) -> jnp.ndarray:
    """Paged twin of :func:`gear_attend`: decode attention whose compressed
    history lives in pool pages addressed through ``block_tables [B, C]``.

    The fused path is :func:`gear_decode_paged` (scalar-prefetched tables,
    page gather in the DMA engine); off-TPU the
    :func:`~repro.kernels.ref.gear_decode_paged_ref` oracle gathers the
    pool and defers to the dense oracle.  The FP16 streaming buffer is
    per-slot (not paged) and merges through the same
    :func:`_merge_buffer` tail as the dense path, so a paged slot's output
    is bit-identical to the dense slot's for the same history.
    """
    pol = cfg.policy
    B, Hq, Dh = q.shape
    H = cfg.kv_heads
    G = Hq // H
    BH = B * H
    qf = q.astype(jnp.float32).reshape(BH, G, Dh)
    nb = cfg.chunk
    length = jnp.broadcast_to(jnp.asarray(pcache.length, jnp.int32), (B,))
    len_bh = jnp.repeat(length, H)
    n_comp = (len_bh // nb) * nb
    n_buf = len_bh - n_comp
    bt = jnp.asarray(block_tables, jnp.int32)

    kwargs = dict(bits=pol.bits, chunk=nb, scale_factor=scale)
    arrays, lr, sp = _paged_operands(cfg, pcache)
    common = (qf, *arrays, n_comp, bt)
    if on_tpu() or force_kernel:
        acc, m, l = gear_decode_paged(*common,
                                      interpret=interpret or not on_tpu(),
                                      **kwargs, **lr, **sp)
        m, l = m[..., 0], l[..., 0]
    else:
        acc, m, l = ref_ops.gear_decode_paged_ref(*common, **kwargs,
                                                  **lr, **sp)

    out = _merge_buffer(cfg, pcache, qf, acc, m, l, n_buf, scale)
    return out.reshape(B, Hq, Dh).astype(q.dtype)


def _block_divisor(s: int, target: int) -> int:
    """Largest block size <= target dividing s (flash kernel tiling)."""
    c = min(target, s)
    while s % c:
        c //= 2
    return max(c, 1)


def flash_attention(q, k, v, *, window: int = 0, prefix_len: int = 0,
                    softcap: float = 0.0, kv_repeat: int = 1,
                    interpret: bool = False, bq: int = 128, bk: int = 128):
    """q: [BHq, S, Dh], k/v: [BHq/kv_repeat, S, Dh] causal attention;
    kernel on TPU, oracle elsewhere.

    ``kv_repeat`` > 1 is GQA: the kernel indexes each query head group onto
    its shared K/V row (no broadcast copy).  Block sizes are snapped down
    to divisors of S, so any (padded prompt) length the engine produces is
    legal.
    """
    S = q.shape[1]
    if on_tpu() or interpret:
        return flash_prefill(q, k, v, bq=_block_divisor(S, bq),
                             bk=_block_divisor(S, bk), window=window,
                             prefix_len=prefix_len, softcap=softcap,
                             kv_repeat=kv_repeat,
                             interpret=interpret and not on_tpu())
    if kv_repeat > 1:            # CPU oracle path: plain repeat is fine
        k = jnp.repeat(k, kv_repeat, axis=0)
        v = jnp.repeat(v, kv_repeat, axis=0)
    return ref_ops.flash_prefill_ref(q, k, v, jnp.arange(S), causal=True,
                                     window=window, prefix_len=prefix_len,
                                     softcap=softcap)


def quantize_chunk(x: jnp.ndarray, bits: int, interpret: bool = False):
    """Fused per-channel quantize+pack of a chunk batch [N, n, d]."""
    if on_tpu() or interpret:
        return quant_pack(x, bits, interpret=interpret or not on_tpu())
    return ref_ops.quant_pack_ref(x, bits)
