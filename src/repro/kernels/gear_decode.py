"""Pallas TPU kernel: fused GEAR decode attention.

The TPU-native analogue of the paper's fused CUDA dequant+GEMM: one decode
step attends over the compressed cache without ever materializing the FP16
K/V in HBM.  Per grid step (bh, c) the kernel:

  1. streams one chunk's packed K codes (int32 lanes) into VMEM, unpacks
     with vectorized shift/mask, applies per-channel scale/zero,
  2. densifies the chunk's sparse outliers (iota-compare scatter — 2·k
     vector ops, no gather hardware needed),
  3. adds the low-rank score path factored as (q·B_c)·A_cᵀ — the paper's
     separate-path trick, two rank-r matmuls instead of an [nb, Dh] add,
  4. runs online-softmax accumulation in VMEM scratch across chunks, with
     the V side dequantized/densified the same way.

Outputs are the *unnormalized* (acc, m, l) triple so the caller merges the
FP16 streaming-buffer region (computed in plain XLA — it is n_b tokens) and
normalizes once.  HBM traffic per step ≈ packed bits + stats + factors
≈ (bits/16 + overheads) × the FP16 cache — the memory-roofline win that
produces the paper's throughput gain on memory-bound decode.

Grid: (BH, C).  Block shapes are MXU/VPU aligned: Dh ∈ {64, 128, 256} maps
to lane-dim 128 tiles; the chunk dim (n_b = 64/128) is the sublane dim.

**Ragged batches.**  ``n_comp`` may be a scalar (all slots at one extent) or
a per-row ``[BH]`` vector: each (bh, c) grid program reads its own row's
compressed extent and masks chunk scores past it, so mixed-length continuous
batches run the fused path directly.  A row at extent 0 accumulates an
all-masked (uniform) softmax over its own cache rows: when the row's buffer
holds tokens, the caller's ``exp(m - m_tot)`` correction zeroes that weight;
when the row is fully empty (length 0), the correction is exp(0) = 1 and the
output is the mean of the slot's cache rows — zeros because ``reset_slot``
zeroes the slot's bytes, exactly matching the oracle.  Either way the math is
per-row only (no cross-slot leakage, no NaN).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gear_decode", "gear_decode_paged"]

NEG_INF = -1e30


def _unpack(packed, bits: int, d: int):
    """packed [n, d//per] int32 -> codes f32 [n, d]."""
    per = 32 // bits
    n = packed.shape[0]
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, None, :]
    codes = (packed.astype(jnp.uint32)[:, :, None] >> shifts) & jnp.uint32(2**bits - 1)
    return codes.reshape(n, d).astype(jnp.float32)


def _kernel(n_comp_ref, q_ref, kp_ref, ks_ref, kz_ref, vp_ref, vs_ref, vz_ref,
            ka_ref, kb_ref, va_ref, vb_ref,
            ksv_ref, ksi_ref, vsv_ref, vsi_ref,
            acc_ref, m_ref, l_ref,
            *, bits: int, chunk: int, scale_factor: float,
            use_lr: bool, use_sp: bool):
    c = pl.program_id(1)
    nb = chunk
    q = q_ref[0].astype(jnp.float32)                       # [G, Dh]
    G, Dh = q.shape

    # ---- K chunk: dequant + outliers --------------------------------------
    k_tile = _unpack(kp_ref[0], bits, Dh)                  # [nb, Dh]
    k_tile = k_tile * ks_ref[0].astype(jnp.float32) + kz_ref[0].astype(jnp.float32)
    if use_sp:
        ksv = ksv_ref[0, 0].astype(jnp.float32)            # [Dh, Ks]
        ksi = ksi_ref[0, 0]
        row = jax.lax.broadcasted_iota(jnp.int32, (nb, Dh), 0)
        for j in range(ksv.shape[-1]):
            k_tile += jnp.where(row == ksi[None, :, j], ksv[None, :, j], 0.0)

    s = jax.lax.dot_general(q, k_tile, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, nb]
    if use_lr:
        kb = kb_ref[0, 0].astype(jnp.float32)              # [Dh, r]
        ka = ka_ref[0].astype(jnp.float32)                 # [nb, r]
        qb = jax.lax.dot_general(q, kb, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [G, r]
        s += jax.lax.dot_general(qb, ka, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    s = s * scale_factor

    tok = c * nb + jax.lax.broadcasted_iota(jnp.int32, (G, nb), 1)
    s = jnp.where(tok < n_comp_ref[0], s, NEG_INF)

    # ---- V chunk ------------------------------------------------------------
    v_tile = _unpack(vp_ref[0], bits, Dh)
    gv = vs_ref.shape[-1]
    vsc = jnp.repeat(vs_ref[0].astype(jnp.float32), Dh // gv, axis=-1)
    vzr = jnp.repeat(vz_ref[0].astype(jnp.float32), Dh // gv, axis=-1)
    v_tile = v_tile * vsc + vzr
    if use_sp:
        vsv = vsv_ref[0].astype(jnp.float32)               # [nb, Kv]
        vsi = vsi_ref[0]
        col = jax.lax.broadcasted_iota(jnp.int32, (nb, Dh), 1)
        for j in range(vsv.shape[-1]):
            v_tile += jnp.where(col == vsi[:, j][:, None], vsv[:, j][:, None], 0.0)

    # ---- online softmax -----------------------------------------------------
    @pl.when(c == 0)
    def _init():
        acc_ref[0] = jnp.zeros_like(acc_ref[0])
        m_ref[0] = jnp.full_like(m_ref[0], NEG_INF)
        l_ref[0] = jnp.zeros_like(l_ref[0])

    m_prev = m_ref[0][:, 0]                                # [G]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                        # [G, nb]
    l_ref[0] = l_ref[0] * corr[:, None] + jnp.sum(p, axis=-1)[:, None]
    pv = jax.lax.dot_general(p, v_tile, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if use_lr:
        va = va_ref[0].astype(jnp.float32)                 # [nb, r]
        vb = vb_ref[0, 0].astype(jnp.float32)              # [Dh, r]
        pa = jax.lax.dot_general(p, va, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [G, r]
        pv += jax.lax.dot_general(pa, vb, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    acc_ref[0] = acc_ref[0] * corr[:, None] + pv
    m_ref[0] = jnp.broadcast_to(m_new[:, None], m_ref[0].shape)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "chunk", "scale_factor", "interpret"),
)
def gear_decode(
    q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero, n_comp,
    k_a=None, k_b=None, v_a=None, v_b=None,
    k_sp_val=None, k_sp_idx=None, v_sp_val=None, v_sp_idx=None,
    *, bits: int, chunk: int, scale_factor: float, interpret: bool = False,
):
    """See ref.gear_decode_ref for the contract.  Returns (acc, m, l).

    ``n_comp``: scalar or per-row [BH] int32 compressed extents (ragged).
    """
    BH, G, Dh = q.shape
    S = k_packed.shape[1]
    C = S // chunk
    Lp = k_packed.shape[-1]
    use_lr = k_a is not None
    use_sp = k_sp_val is not None
    r = k_a.shape[-1] if use_lr else 1
    ks2 = k_sp_val.shape[-1] if use_sp else 1
    kv2 = v_sp_val.shape[-1] if use_sp else 1
    gv = v_scale.shape[-1]
    f32 = jnp.float32

    # dummy placeholders keep the kernel signature static
    if not use_lr:
        k_a = jnp.zeros((BH, S, 1), f32); k_b = jnp.zeros((BH, C, Dh, 1), f32)
        v_a = jnp.zeros((BH, S, 1), f32); v_b = jnp.zeros((BH, C, Dh, 1), f32)
    if not use_sp:
        k_sp_val = jnp.zeros((BH, C, Dh, 1), f32)
        k_sp_idx = jnp.full((BH, C, Dh, 1), -1, jnp.int32)
        v_sp_val = jnp.zeros((BH, S, 1), f32)
        v_sp_idx = jnp.full((BH, S, 1), -1, jnp.int32)

    # scalar extents broadcast to one row per (batch, head) grid program
    n_comp_arr = jnp.broadcast_to(jnp.asarray(n_comp, jnp.int32), (BH,))

    grid = (BH, C)
    kernel = functools.partial(
        _kernel, bits=bits, chunk=chunk, scale_factor=scale_factor,
        use_lr=use_lr, use_sp=use_sp)
    out_shape = (
        jax.ShapeDtypeStruct((BH, G, Dh), f32),
        jax.ShapeDtypeStruct((BH, G, 128), f32),
        jax.ShapeDtypeStruct((BH, G, 128), f32),
    )
    bh = lambda x, c: (x, 0, 0)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda x, c: (x,)),                       # n_comp[bh]
            pl.BlockSpec((1, G, Dh), bh),                                # q
            pl.BlockSpec((1, chunk, Lp), lambda x, c: (x, c, 0)),        # k_packed
            pl.BlockSpec((1, 1, Dh), lambda x, c: (x, c, 0)),            # k_scale
            pl.BlockSpec((1, 1, Dh), lambda x, c: (x, c, 0)),            # k_zero
            pl.BlockSpec((1, chunk, Lp), lambda x, c: (x, c, 0)),        # v_packed
            pl.BlockSpec((1, chunk, gv), lambda x, c: (x, c, 0)),        # v_scale
            pl.BlockSpec((1, chunk, gv), lambda x, c: (x, c, 0)),        # v_zero
            pl.BlockSpec((1, chunk, r), lambda x, c: (x, c, 0)),         # k_a
            pl.BlockSpec((1, 1, Dh, r), lambda x, c: (x, c, 0, 0)),      # k_b
            pl.BlockSpec((1, chunk, r), lambda x, c: (x, c, 0)),         # v_a
            pl.BlockSpec((1, 1, Dh, r), lambda x, c: (x, c, 0, 0)),      # v_b
            pl.BlockSpec((1, 1, Dh, ks2), lambda x, c: (x, c, 0, 0)),    # k_sp_val
            pl.BlockSpec((1, 1, Dh, ks2), lambda x, c: (x, c, 0, 0)),    # k_sp_idx
            pl.BlockSpec((1, chunk, kv2), lambda x, c: (x, c, 0)),       # v_sp_val
            pl.BlockSpec((1, chunk, kv2), lambda x, c: (x, c, 0)),       # v_sp_idx
        ],
        out_specs=(
            pl.BlockSpec((1, G, Dh), bh),
            pl.BlockSpec((1, G, 128), bh),
            pl.BlockSpec((1, G, 128), bh),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(n_comp_arr, q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero,
      k_a, k_b, v_a, v_b, k_sp_val, k_sp_idx, v_sp_val, v_sp_idx)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "chunk", "scale_factor", "interpret"),
)
def gear_decode_paged(
    q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero, n_comp,
    block_tables,
    k_a=None, k_b=None, v_a=None, v_b=None,
    k_sp_val=None, k_sp_idx=None, v_sp_val=None, v_sp_idx=None,
    *, bits: int, chunk: int, scale_factor: float, interpret: bool = False,
):
    """Paged twin of :func:`gear_decode`: same kernel body, same math, but
    the compressed operands are *head-flattened pool pages* addressed
    through scalar-prefetched block tables instead of contiguous rows.

    Pool operands are ``[P*H, ...one-chunk-block]`` (a pool leaf
    ``[P, H, ...]`` reshaped by the caller): page ``p``, head ``h`` lives at
    row ``p*H + h``.  ``block_tables [B, C]`` arrives via
    ``PrefetchScalarGridSpec`` so every BlockSpec index map can compute its
    DMA source ``row = bt[bh // H, c] * H + bh % H`` before the grid step
    runs — the gather happens in the DMA engine, not as kernel gather ops.
    Because the pool's page 0 is the reserved zero page and fresh pages are
    zeroed at admission, out-of-extent table entries stream the same zero
    bytes the dense layout holds there, and the accumulated (acc, m, l)
    triple is bit-identical to :func:`gear_decode` on the gathered-dense
    cache.  ``n_comp`` masking is unchanged (ragged per-row extents).
    """
    BH, G, Dh = q.shape
    B, C = block_tables.shape
    H = BH // B
    Lp = k_packed.shape[-1]
    use_lr = k_a is not None
    use_sp = k_sp_val is not None
    r = k_a.shape[-1] if use_lr else 1
    ks2 = k_sp_val.shape[-1] if use_sp else 1
    kv2 = v_sp_val.shape[-1] if use_sp else 1
    gv = v_scale.shape[-1]
    nb = chunk
    f32 = jnp.float32

    # page-row index map shared by every pool operand: the chunk coordinate
    # is consumed by the block-table lookup, the block covers the whole page
    def prow(*tail):
        return lambda x, c, bt: ((bt[x // H, c] * H + x % H).astype(jnp.int32),
                                 *tail)

    # dummy single-page operands when the policy has no low-rank / sparse
    # fields; their index maps pin to row 0 so no table lookup happens
    zrow = lambda *tail: (lambda x, c, bt: (0, *tail))
    if not use_lr:
        k_a = jnp.zeros((1, nb, 1), f32); k_b = jnp.zeros((1, 1, Dh, 1), f32)
        v_a = jnp.zeros((1, nb, 1), f32); v_b = jnp.zeros((1, 1, Dh, 1), f32)
    if not use_sp:
        k_sp_val = jnp.zeros((1, 1, Dh, 1), f32)
        k_sp_idx = jnp.full((1, 1, Dh, 1), -1, jnp.int32)
        v_sp_val = jnp.zeros((1, nb, 1), f32)
        v_sp_idx = jnp.full((1, nb, 1), -1, jnp.int32)
    lr_row = prow if use_lr else zrow
    sp_row = prow if use_sp else zrow

    n_comp_arr = jnp.broadcast_to(jnp.asarray(n_comp, jnp.int32), (BH,))
    bt = jnp.asarray(block_tables, jnp.int32)

    def kernel(bt_ref, *refs):
        del bt_ref  # consumed by the index maps
        _kernel(*refs, bits=bits, chunk=chunk, scale_factor=scale_factor,
                use_lr=use_lr, use_sp=use_sp)

    bh = lambda x, c, bt: (x, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, C),
        in_specs=[
            pl.BlockSpec((1,), lambda x, c, bt: (x,)),             # n_comp[bh]
            pl.BlockSpec((1, G, Dh), bh),                          # q
            pl.BlockSpec((1, chunk, Lp), prow(0, 0)),              # k_packed
            pl.BlockSpec((1, 1, Dh), prow(0, 0)),                  # k_scale
            pl.BlockSpec((1, 1, Dh), prow(0, 0)),                  # k_zero
            pl.BlockSpec((1, chunk, Lp), prow(0, 0)),              # v_packed
            pl.BlockSpec((1, chunk, gv), prow(0, 0)),              # v_scale
            pl.BlockSpec((1, chunk, gv), prow(0, 0)),              # v_zero
            pl.BlockSpec((1, chunk, r), lr_row(0, 0)),             # k_a
            pl.BlockSpec((1, 1, Dh, r), lr_row(0, 0, 0)),          # k_b
            pl.BlockSpec((1, chunk, r), lr_row(0, 0)),             # v_a
            pl.BlockSpec((1, 1, Dh, r), lr_row(0, 0, 0)),          # v_b
            pl.BlockSpec((1, 1, Dh, ks2), sp_row(0, 0, 0)),        # k_sp_val
            pl.BlockSpec((1, 1, Dh, ks2), sp_row(0, 0, 0)),        # k_sp_idx
            pl.BlockSpec((1, chunk, kv2), sp_row(0, 0)),           # v_sp_val
            pl.BlockSpec((1, chunk, kv2), sp_row(0, 0)),           # v_sp_idx
        ],
        out_specs=(
            pl.BlockSpec((1, G, Dh), bh),
            pl.BlockSpec((1, G, 128), bh),
            pl.BlockSpec((1, G, 128), bh),
        ),
    )
    out_shape = (
        jax.ShapeDtypeStruct((BH, G, Dh), f32),
        jax.ShapeDtypeStruct((BH, G, 128), f32),
        jax.ShapeDtypeStruct((BH, G, 128), f32),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(bt, n_comp_arr, q, k_packed, k_scale, k_zero, v_packed, v_scale,
      v_zero, k_a, k_b, v_a, v_b, k_sp_val, k_sp_idx, v_sp_val, v_sp_idx)
