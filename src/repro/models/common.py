"""Shared model components: norms, activations, RoPE, init helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm", "layernorm", "apply_norm", "norm_params",
    "rope_freqs", "apply_rope", "dense_init", "KeyGen",
]


class KeyGen:
    """Deterministic PRNG key dispenser for parameter init."""

    def __init__(self, key: jax.Array):
        self._key = key
        self._n = 0

    def __call__(self) -> jax.Array:
        self._n += 1
        return jax.random.fold_in(self._key, self._n)


def dense_init(key: jax.Array, shape, fan_in: int | None = None,
               dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal init scaled by 1/sqrt(fan_in) (LLM standard)."""
    if fan_in is None:
        fan_in = shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def norm_params(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}  # gemma-style (1+scale)
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x: jnp.ndarray, params, kind: str) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: [B, S, H, Dh] or [B, S, Dh].

    positions: [S] (shared across the batch) or [B, S] (per-slot positions —
    the continuous-batching decode path, where every batch row sits at its
    own absolute position).
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [S, Dh/2] | [B, S, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]                     # -> [1, S, Dh/2]
    if x.ndim == 4:  # head axis present
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
