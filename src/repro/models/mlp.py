"""Feed-forward variants: SwiGLU (llama), GeGLU (gemma), plain GELU MLP
(starcoder2/musicgen)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init

__all__ = ["mlp_params", "mlp_apply"]


def mlp_params(cfg: ModelConfig, kg: KeyGen, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(kg(), (d, ff)),
            "w_up": dense_init(kg(), (d, ff)),
            "w_down": dense_init(kg(), (ff, d), fan_in=ff),
        }
    return {
        "w_up": dense_init(kg(), (d, ff)),
        "w_down": dense_init(kg(), (ff, d), fan_in=ff),
    }


def mlp_apply(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"].astype(dt)) * (x @ params["w_up"].astype(dt))
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"].astype(dt), approximate=True) * (
            x @ params["w_up"].astype(dt))
    else:  # gelu_mlp
        h = jax.nn.gelu(x @ params["w_up"].astype(dt), approximate=True)
    return h @ params["w_down"].astype(dt)
