"""Multi-head attention: GQA/MQA, RoPE, QK-norm, logit softcap, sliding
window, prefix-LM; full-sequence (train/prefill) and cached-decode paths.

The full-sequence path chunks queries with ``lax.scan`` so the score matrix
never exceeds ``[B, H, q_chunk, S]`` — required for the 32k prefill shapes.
The decode path runs against any :mod:`repro.core.cache` layer cache (GEAR,
fp16, or sliding-window ring buffer).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.kernels import ops as kernel_ops
from repro.models.common import KeyGen, apply_rope, dense_init, rmsnorm

__all__ = ["attn_params", "attention_train", "attention_decode",
           "attention_prefill_streaming", "streaming_prefill_supported",
           "rope_theta_for"]

NEG_INF = -1e30


def rope_theta_for(cfg: ModelConfig, kind: str) -> float:
    # gemma3-style dual RoPE: local layers use short-range theta.
    if kind == "local" and cfg.attn_pattern == "local_global":
        return 10_000.0
    return cfg.rope_theta


def attn_params(cfg: ModelConfig, kg: KeyGen) -> dict:
    d, qd, kvd, dh = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    p = {
        "wq": dense_init(kg(), (d, qd)),
        "wk": dense_init(kg(), (d, kvd)),
        "wv": dense_init(kg(), (d, kvd)),
        "wo": dense_init(kg(), (qd, d), fan_in=qd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, params, x, positions, kind: str):
    B, S, _ = x.shape
    dh = cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, dh)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    theta = rope_theta_for(cfg, kind)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _mask(q_pos, k_pos, kind: str, window: int, prefix_len: int):
    """[... , Sq, Sk] additive-mask boolean: True = attend."""
    causal = q_pos[:, None] >= k_pos[None, :]
    ok = causal
    if kind == "local":
        ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
    if prefix_len:
        both_prefix = (q_pos[:, None] < prefix_len) & (k_pos[None, :] < prefix_len)
        ok = ok | both_prefix
    return ok


def _sdpa_chunked(cfg: ModelConfig, q, k, v, positions, kind: str,
                  prefix_len: int, q_chunk: int):
    """q: [B,S,Hq,Dh]; k,v: [B,S,Hkv,Dh] -> [B,S,Hq,Dh].  Scans q chunks."""
    B, S, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = Dh ** -0.5
    cap = cfg.attn_logit_softcap
    kT = jnp.moveaxis(k, 1, 2)  # [B,Hkv,S,Dh]
    vT = jnp.moveaxis(v, 1, 2)
    k_pos = positions

    def block(q_blk, pos_blk):
        # q_blk: [B, qc, Hq, Dh].  Scores/probs materialize bf16 (MXU
        # accumulates f32 internally); softmax internals run f32 fused —
        # the standard TPU mixed-precision attention layout.
        qg = jnp.moveaxis(q_blk, 1, 2).reshape(B, Hkv, G, q_blk.shape[1], Dh)
        s = jnp.einsum("bhgqd,bhsd->bhgqs", qg.astype(jnp.bfloat16),
                       kT.astype(jnp.bfloat16),
                       preferred_element_type=jnp.bfloat16) * scale
        if cap:
            s = (cap * jnp.tanh(s.astype(jnp.float32) / cap)).astype(jnp.bfloat16)
        m = _mask(pos_blk, k_pos, kind, cfg.local_window, prefix_len)
        s = jnp.where(m[None, None, None], s, jnp.bfloat16(NEG_INF))
        mx = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        ex = jnp.exp((s - mx).astype(jnp.float32))
        w = (ex / jnp.sum(ex, axis=-1, keepdims=True)).astype(jnp.bfloat16)
        # bf16 output materialization: the MXU still accumulates f32
        # internally, and this keeps the transposed (backward) dot's
        # cotangent bf16 too (§Perf iteration 3).
        o = jnp.einsum("bhgqs,bhsd->bhgqd", w, vT.astype(jnp.bfloat16),
                       preferred_element_type=jnp.bfloat16)
        return jnp.moveaxis(o.reshape(B, Hq, q_blk.shape[1], Dh), 1, 2)

    if S <= q_chunk:
        return block(q, positions).astype(q.dtype)
    assert S % q_chunk == 0, (S, q_chunk)
    nblk = S // q_chunk
    q_blocks = jnp.moveaxis(q.reshape(B, nblk, q_chunk, Hq, Dh), 1, 0)
    pos_blocks = positions.reshape(nblk, q_chunk)
    _, out = jax.lax.scan(lambda c, xs: (c, block(*xs)), None, (q_blocks, pos_blocks))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, Hq, Dh).astype(q.dtype)


def _sdpa_flash(cfg: ModelConfig, q, k, v, kind: str, prefix_len: int,
                interpret: bool):
    """Full-sequence attention through the ``flash_prefill`` Pallas kernel.

    q: [B,S,Hq,Dh]; k,v: [B,S,Hkv,Dh] -> [B,S,Hq,Dh].  GQA lays query rows
    out (B, Hkv, G) head-major and the kernel's ``kv_repeat`` index map
    points each group at its shared K/V row — no G-fold broadcast copy of
    K/V is ever materialized.  Same mask family as ``_sdpa_chunked``
    (causal / sliding window / bidirectional prefix, plus logit softcap).
    """
    B, S, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    window = cfg.local_window if kind == "local" else 0
    qh = jnp.moveaxis(q, 1, 2).reshape(B * Hq, S, Dh)   # (B, Hkv, G) rows
    out = kernel_ops.flash_attention(
        qh, jnp.moveaxis(k, 1, 2).reshape(B * Hkv, S, Dh),
        jnp.moveaxis(v, 1, 2).reshape(B * Hkv, S, Dh),
        window=window, prefix_len=prefix_len, softcap=cfg.attn_logit_softcap,
        kv_repeat=G, interpret=interpret)
    return jnp.moveaxis(out.reshape(B, Hq, S, Dh), 1, 2)


def attention_train(cfg: ModelConfig, params, x, positions, kind: str = "global",
                    prefix_len: int = 0, q_chunk: int = 512,
                    impl: str = "chunked"):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v)).

    k/v are returned [B, Hkv, S, Dh] for optional cache construction.
    ``impl`` selects the score path: "chunked" (lax.scan'd XLA blocks — the
    training default), "flash" (the ``flash_prefill`` Pallas kernel — the
    monolithic-prefill fast path on TPU), or "flash-interpret" (kernel in
    interpret mode, CI parity lane).
    """
    q, k, v = _project_qkv(cfg, params, x, positions, kind)
    if impl in ("flash", "flash-interpret"):
        out = _sdpa_flash(cfg, q, k, v, kind, prefix_len,
                          interpret=impl == "flash-interpret")
    else:
        out = _sdpa_chunked(cfg, q, k, v, positions, kind, prefix_len, q_chunk)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.q_dim).astype(x.dtype) @ params["wo"].astype(x.dtype)
    return out, (jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2))


def streaming_prefill_supported(cfg: ModelConfig, kind: str,
                                cache_cfg) -> bool:
    """Layers that can take the streaming chunked-prefill pipeline.

    Requires the streaming cache layout (GEAR with per-channel K stats at
    chunk granularity — :func:`repro.core.cache.streaming_supported`; fp16
    has no compression event, window layers keep their ring buffer) and
    plain causal attention: the factored history scores have no
    sliding-window or bidirectional-prefix mask, and — like the
    cached-decode path — no logit softcap.  Unsupported layers fall back
    to monolithic prefill under the same knob.  Static (config-only), so
    the dispatch never splits a jitted program.
    """
    return (cache_lib.streaming_supported(cache_cfg) and kind != "local"
            and cfg.attn_logit_softcap == 0.0
            and not (cfg.modality == "vlm" and cfg.num_prefix_tokens))


def attention_prefill_streaming(cfg: ModelConfig, params, x, positions,
                                kind: str, cache_cfg, key=None,
                                fused: str = "auto", dtype=jnp.bfloat16,
                                cache=None, start_pos: int = 0,
                                padded_tail: bool = False, true_len=None):
    """Streaming chunked prefill of one attention layer: project → compress
    → attend, one ``n_b``-token chunk at a time under two carry-free
    ``lax.scan`` passes (loop fission of the compress-as-you-go pipeline —
    see :func:`repro.core.cache.streaming_prefill_layer_cache`).

    Q/K/V are projected *per chunk inside the scans*, so the full-sequence
    FP16 K/V never exists: peak memory is the compressed cache plus one
    chunk of K/V and scores.  The compression scan closes every chunk
    through the (optionally fused) compression event and its stacked
    outputs are stored once; the attend scan then runs each chunk's
    queries against the compressed history *before* that chunk (scores
    masked at ``c · n_b``) plus the in-flight FP16 chunk via a two-piece
    online softmax — the same semantics decode already has (compressed
    history + FP16 buffer).  Leftover tokens land in the streaming buffer.
    Returns (out [B, S, d_model], layer cache); the cache is bit-identical
    to a monolithic prefill of the same tokens.

    ``start_pos`` > 0 (with ``cache`` holding ``start_pos / n_b`` chunks
    already spliced from the prefix cache) runs the suffix path: ``x`` /
    ``positions`` cover only the tokens after the cached prefix, new
    chunks are stored from that offset, and every attend sees the cached
    chunks as compressed history — bit-identical to the cold prefill that
    would have computed them (DESIGN.md §4).

    ``padded_tail=True`` (with ``true_len`` the traced real token count)
    marks ``x`` as length-bucketed: ``S`` is a chunk multiple whose last
    ``n_b`` block is right-padded.  That block stays out of the compression
    scan and lands in the FP16 streaming buffer; see
    :func:`repro.core.cache.streaming_prefill_pipeline`.
    """
    B, S, _ = x.shape
    nb = cache_cfg.chunk
    if start_pos % nb:
        raise ValueError(f"start_pos {start_pos} not aligned to chunk {nb}")
    if padded_tail and S % nb:
        raise ValueError(f"padded_tail needs S % n_b == 0 (S={S}, n_b={nb})")
    scale = cfg.head_dim ** -0.5
    if cache is None:
        cache = cache_lib.init_layer_cache(cache_cfg, dtype)
    C_new = S // nb - 1 if padded_tail else S // nb
    n_full = C_new * nb

    def project(x_blk_pos):
        x_blk, pos_blk = x_blk_pos
        q, k, v = _project_qkv(cfg, params, x_blk, pos_blk, kind)
        return (jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                jnp.moveaxis(v, 1, 2))                       # [B, H*, T, Dh]

    chunk_xs = None
    if C_new:
        chunk_xs = (jnp.moveaxis(x[:, :n_full].reshape(B, C_new, nb, -1), 1, 0),
                    positions[:n_full].reshape(C_new, nb))
    tail_x = (x[:, n_full:], positions[n_full:]) if S > n_full else None
    cache, out = cache_lib.streaming_prefill_pipeline(
        cache_cfg, cache, S, chunk_xs, tail_x, project, scale, key, fused,
        start_chunk=start_pos // nb, tail_is_padded=padded_tail,
        true_n=true_len)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, cfg.q_dim).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), cache


def attention_decode(cfg: ModelConfig, params, x_t, pos, cache, cache_cfg,
                     kind: str = "global", fused: str = "auto",
                     block_tables=None):
    """One-token attention against a layer cache.

    x_t: [B, 1, d]; pos: int32 absolute position — scalar (all slots aligned)
    or [B] (per-slot positions, continuous batching).  Both shapes go through
    the same per-slot RoPE path so wave-mode and spliced-slot decodes are
    bit-identical per batch row.

    ``fused`` selects the attend path for GEAR caches in the fused-kernel
    layout (:func:`repro.kernels.ops.fused_supported`):
      "auto"      — fused :func:`repro.kernels.ops.gear_attend` (Pallas
                    kernel on TPU, jnp oracle elsewhere); ragged-aware, so
                    mixed-length continuous batches take it too;
      "interpret" — force the Pallas kernel in interpret mode (CI kernel
                    lane: exercises kernel code through the serving stack);
      "off"       — the portable :func:`repro.core.cache.attend` path.
    The choice is static (layout-based, never length-based) so wave and
    continuous modes share one numeric program per configuration.

    A :class:`~repro.core.cache.PagedGEARLayerCache` takes the same paths
    with its pooled twins (``append_token_paged`` + ``gear_attend_paged`` /
    ``attend_paged``); ``block_tables [B, C]`` is required then — it is
    engine-owned metadata like ``pos``, threaded per call rather than
    stored in the cache.  Returns (out [B, 1, d], new_cache).
    """
    B = x_t.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape((-1, 1)), (B, 1))
    q, k, v = _project_qkv(cfg, params, x_t, positions, kind)
    k_t = jnp.squeeze(k, axis=1)  # [B, Hkv, Dh]
    v_t = jnp.squeeze(v, axis=1)
    q_t = jnp.squeeze(q, axis=1)  # [B, Hq, Dh]
    scale = cfg.head_dim ** -0.5
    # NOTE: logit softcap is omitted on the cached-decode path (it only
    # matters for training stability); documented in DESIGN.md.
    if isinstance(cache, cache_lib.PagedGEARLayerCache):
        if block_tables is None:
            raise ValueError("paged cache decode needs block_tables")
        new_cache = cache_lib.append_token_paged(cache_cfg, cache,
                                                 block_tables, k_t, v_t)
        if fused != "off" and kernel_ops.fused_supported(cache_cfg):
            out = kernel_ops.gear_attend_paged(
                cache_cfg, new_cache, block_tables, q_t, scale=scale,
                force_kernel=fused == "interpret",
                interpret=fused == "interpret")
        else:
            out = cache_lib.attend_paged(cache_cfg, new_cache, block_tables,
                                         q_t, scale)
        out = out.reshape(B, 1, cfg.q_dim) @ params["wo"].astype(x_t.dtype)
        return out, new_cache
    new_cache = cache_lib.append_token(cache_cfg, cache, k_t, v_t)
    if fused != "off" and kernel_ops.fused_supported(cache_cfg):
        out = kernel_ops.gear_attend(cache_cfg, new_cache, q_t,
                                     scale=scale,
                                     force_kernel=fused == "interpret",
                                     interpret=fused == "interpret")
    else:
        out = cache_lib.attend(cache_cfg, new_cache, q_t, scale=scale)
    out = out.reshape(B, 1, cfg.q_dim) @ params["wo"].astype(x_t.dtype)
    return out, new_cache
