"""Mixture-of-Experts with capacity-based index dispatch (expert parallel).

Dispatch avoids both the GShard one-hot combine tensor (O(T·E·C) FLOPs) and
a global argsort: per-expert slot positions come from a cumulative count of
assignments, tokens are gathered into an ``[E, C, d]`` buffer (sharded E →
``model`` axis, C → ``data`` axis, so XLA lowers the exchange to all-to-all
collectives), experts run as one batched einsum on the MXU, and results
gather back with router weights.  Overflow beyond capacity is dropped
(standard Switch behaviour; capacity_factor 1.25 default).

Implements both assigned MoE archs: qwen3-moe (128e top-8) and llama4-scout
(16e top-1 + shared expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init
from repro.models.mlp import mlp_params, mlp_apply

__all__ = ["moe_params", "moe_apply", "capacity_for"]


def capacity_for(cfg: ModelConfig, tokens: int) -> int:
    cap = int(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_params(cfg: ModelConfig, kg: KeyGen) -> dict:
    d, E, ffe = cfg.d_model, cfg.num_experts, cfg.expert_ff
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    p = {
        "router": dense_init(kg(), (d, E)),
        "w_up": dense_init(kg(), (E, d, ffe)),
        "w_down": dense_init(kg(), (E, ffe, d), fan_in=ffe),
    }
    if gated:
        p["w_gate"] = dense_init(kg(), (E, d, ffe))
    if cfg.shared_expert:
        p["shared"] = mlp_params(cfg, kg, d_ff=ffe)
    return p


def _expert_ffn(cfg: ModelConfig, params, xe: jnp.ndarray) -> jnp.ndarray:
    """xe: [E, C, d] -> [E, C, d], batched over experts."""
    dt = xe.dtype
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    if "w_gate" in params:
        gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
        act = jax.nn.silu(gate) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(gate, approximate=True)
        h = act * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))


def moe_apply(cfg: ModelConfig, params, x: jnp.ndarray):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.moe_top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                       # [T, E]
    w, eidx = jax.lax.top_k(gates, k)                             # [T, k]
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    # slot assignment: position of each (token, k) pair within its expert
    flat_e = eidx.reshape(T * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)               # [T*k, E]
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]      # [T*k]
    C = capacity_for(cfg, T)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)               # sentinel = drop

    # token index occupying each slot (sentinel row maps to token 0, masked later)
    tok_of_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        jnp.arange(T * k, dtype=jnp.int32) // k)
    slot_used = jnp.zeros((E * C + 1,), jnp.bool_).at[slot].set(keep)
    xe = xt[tok_of_slot[: E * C]] * slot_used[: E * C, None].astype(x.dtype)
    xe = xe.reshape(E, C, d)

    ye = _expert_ffn(cfg, params, xe).reshape(E * C, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    y_pairs = ye[slot].reshape(T, k, d)                           # dropped -> 0
    y = jnp.sum(y_pairs * w[..., None].astype(x.dtype), axis=1)

    if cfg.shared_expert:
        y = y + mlp_apply(cfg, params["shared"], xt)

    # Switch-style load-balance auxiliary loss
    me = jnp.mean(gates, axis=0)                                  # mean router prob
    top1 = jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(top1, axis=0)                                   # dispatch fraction
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
