"""Chunked linear-recurrence engine shared by the SSM and RWKV6 blocks.

Computes, for per-head state ``S ∈ R^{Dk×Dv}``:

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    y_t = r_tᵀ · S_t                          (mode="inclusive", Mamba-style)
    y_t = r_tᵀ · (S_{t-1} + diag(u) k_t v_tᵀ) (mode="bonus", RWKV6 Finch)

in **chunked parallel form**: sequence split into chunks of ``chunk`` tokens;
within a chunk the contribution is a masked matmul with cumulative-decay
factors (parallel, MXU-friendly); across chunks a short ``lax.scan`` carries
the state.  This is the standard GLA/SSD chunking adapted to TPU: O(S·W)
instead of O(S) sequential steps, O(log) nothing needed.

Numerics: decay factors are handled in log-space.  Intra-chunk ratios
``exp(cum_t − cum_τ)`` (τ ≤ t) are ≤ 1 and exact; the factored form clamps
``−cum`` at :data:`CLAMP` so the k-side factor cannot overflow — positions
whose cumulative decay within one chunk is below e^-30 contribute < 1e-13
and are uniformly zero in f32 anyway (documented in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunked_scan", "sequential_scan_ref"]

CLAMP = 30.0


def chunked_scan(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,
    chunk: int = 64,
    u: jnp.ndarray | None = None,
    state0: jnp.ndarray | None = None,
    mode: str = "inclusive",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """r,k: [B,H,S,Dk]; v: [B,H,S,Dv]; log_w: [B,H,S,Dk] (≤0) or broadcastable.

    u: bonus vector [H, Dk] (mode="bonus").  Returns (y [B,H,S,Dv],
    final state [B,H,Dk,Dv]).
    """
    B, H, S, Dk = r.shape
    Dv = v.shape[-1]
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    C, W = S // chunk, chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, H, C, W, Dk)
    kc = k.astype(f32).reshape(B, H, C, W, Dk)
    vc = v.astype(f32).reshape(B, H, C, W, Dv)
    lw = jnp.broadcast_to(log_w.astype(f32), (B, H, S, Dk)).reshape(B, H, C, W, Dk)
    if state0 is None:
        state0 = jnp.zeros((B, H, Dk, Dv), f32)
    else:
        state0 = state0.astype(f32)

    cum = jnp.cumsum(lw, axis=3)                       # inclusive Π_{u≤t} w_u
    cum_prev = cum - lw                                # exclusive Π_{u<t} w_u
    q_cum = cum if mode == "inclusive" else cum_prev   # decay applied to state-read
    tri = jnp.tril(jnp.ones((W, W), f32), 0 if mode == "inclusive" else -1)

    # factored intra-chunk attention matrix: att[t,τ] = Σ_dk r_t k_τ e^{qcum_t − cum_τ}
    q_fac = rc * jnp.exp(jnp.maximum(q_cum, -CLAMP))
    k_fac = kc * jnp.exp(jnp.minimum(-cum, CLAMP))
    att = jnp.einsum("bhcwk,bhcxk->bhcwx", q_fac, k_fac) * tri
    y_intra = jnp.einsum("bhcwx,bhcxv->bhcwv", att, vc)
    if mode == "bonus":
        bonus = jnp.einsum("bhcwk,hk,bhcwk->bhcw", rc, u.astype(f32), kc)
        y_intra = y_intra + bonus[..., None] * vc

    # cross-chunk: scan carrying the state
    decay_last = jnp.exp(jnp.maximum(cum[:, :, :, -1, :], -CLAMP))          # [B,H,C,Dk]
    k_state = kc * jnp.exp(jnp.maximum(cum[:, :, :, -1:, :] - cum, -CLAMP))  # Π_{τ<u≤W}
    state_inc = jnp.einsum("bhcwk,bhcwv->bhckv", k_state, vc)               # [B,H,C,Dk,Dv]

    def step(state, xs):
        qf_c, dlast_c, sinc_c = xs
        y_cross = jnp.einsum("bhwk,bhkv->bhwv", qf_c, state)
        state = state * dlast_c[..., None] + sinc_c
        return state, y_cross

    xs = (
        jnp.moveaxis(q_fac, 2, 0),
        jnp.moveaxis(decay_last, 2, 0),
        jnp.moveaxis(state_inc, 2, 0),
    )
    stateT, y_cross = jax.lax.scan(step, state0, xs)
    y = y_intra + jnp.moveaxis(y_cross, 0, 2)
    return y.reshape(B, H, S, Dv).astype(v.dtype), stateT


def decode_step(r_t, k_t, v_t, log_w_t, state, u=None, mode: str = "inclusive"):
    """Single-token recurrence (serving).  r_t/k_t: [B,H,Dk]; v_t: [B,H,Dv];
    state: [B,H,Dk,Dv].  Returns (y_t [B,H,Dv], new state)."""
    f32 = jnp.float32
    rf, kf, vf = r_t.astype(f32), k_t.astype(f32), v_t.astype(f32)
    w = jnp.exp(jnp.broadcast_to(log_w_t.astype(f32), kf.shape))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    if mode == "bonus":
        read = state + u.astype(f32)[None, :, :, None] * kv
        y = jnp.einsum("bhk,bhkv->bhv", rf, read)
        state = state * w[..., None] + kv
    else:
        state = state * w[..., None] + kv
        y = jnp.einsum("bhk,bhkv->bhv", rf, state)
    return y.astype(v_t.dtype), state


def sequential_scan_ref(r, k, v, log_w, u=None, state0=None, mode="inclusive"):
    """O(S) sequential oracle for tests."""
    B, H, S, Dk = r.shape
    Dv = v.shape[-1]
    state = jnp.zeros((B, H, Dk, Dv), jnp.float32) if state0 is None else state0.astype(jnp.float32)
    lw = jnp.broadcast_to(log_w, (B, H, S, Dk))
    ys = []
    for t in range(S):
        y, state = decode_step(r[:, :, t], k[:, :, t], v[:, :, t], lw[:, :, t],
                               state, u=u, mode=mode)
        ys.append(y)
    return jnp.stack(ys, axis=2).astype(v.dtype), state
