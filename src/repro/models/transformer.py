"""Decoder assembly for all assigned architectures.

The layer stack is organized as ``pattern_repeats`` repetitions of a short
``layer_pattern`` unit (e.g. gemma3: LLLLLG ×8; uniform archs: unit of 1).
Parameters and per-layer caches are **stacked over repeats** and the stack is
driven by ``lax.scan`` — compile time stays O(pattern) instead of O(layers),
which is what makes the 94-layer qwen3 dry-run compile quickly.

Block families:
  attn   — [hybrid: ∥ SSM] attention + (MLP | MoE)
  rwkv   — RWKV6 time-mix + channel-mix (attention-free)

Modes: ``train`` (full seq, no cache), ``prefill`` (full seq → caches),
``decode`` (one token, cache update).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core.policy import CompressionPolicy
from repro.kernels import ops as kernel_ops
from repro.models import attention as attn_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.common import KeyGen, apply_norm, dense_init, norm_params
from repro.models.mlp import mlp_apply, mlp_params
from repro.models.moe import moe_apply, moe_params

__all__ = [
    "init_params", "block_params", "forward", "decode_tokens",
    "init_caches", "cache_cfg_for", "pick_q_chunk", "embed_tokens", "logits_from_hidden",
]


def pick_q_chunk(s: int, target: int = 512) -> int:
    c = min(target, s)
    while s % c:
        c //= 2
    return max(c, 1)


# ---------------------------------------------------------------------------
# Parameters


def block_params(cfg: ModelConfig, kg: KeyGen, kind: str) -> dict:
    if kind == "rwkv":
        return {
            "ln1": norm_params(cfg.d_model, "layernorm"),
            "ln2": norm_params(cfg.d_model, "layernorm"),
            **rwkv_lib.rwkv_params(cfg, kg),
        }
    p = {
        "ln1": norm_params(cfg.d_model, cfg.norm),
        "attn": attn_lib.attn_params(cfg, kg),
        "ln2": norm_params(cfg.d_model, cfg.norm),
    }
    if cfg.moe:
        p["moe"] = moe_params(cfg, kg)
    else:
        p["mlp"] = mlp_params(cfg, kg)
    if cfg.ssm and cfg.hybrid_parallel:
        p["ssm"] = ssm_lib.ssm_params(cfg, kg)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    d, v = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {}
    if cfg.modality == "audio":
        params["embed"] = dense_init(kg(), (cfg.num_codebooks, v, d), fan_in=d)
    else:
        params["embed"] = dense_init(kg(), (v, d), fan_in=d)
    if not cfg.tie_embeddings:
        head_v = v * cfg.num_codebooks if cfg.modality == "audio" else v
        params["lm_head"] = dense_init(kg(), (d, head_v))
    params["final_norm"] = norm_params(d, cfg.norm)

    R = cfg.pattern_repeats
    blocks = []
    for kind in cfg.layer_pattern:
        keys = jax.random.split(kg(), R)
        stacked = jax.vmap(lambda k: block_params(cfg, KeyGen(k), kind))(keys)
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    return params


# ---------------------------------------------------------------------------
# Embedding / head


# Activations run in bf16 (mixed precision: f32 master params, f32 norm/
# softmax internals).  Halves every dot operand's HBM traffic — see
# EXPERIMENTS.md §Perf iteration 1.
COMPUTE_DTYPE = jnp.bfloat16


def embed_tokens(cfg: ModelConfig, params, batch: dict) -> jnp.ndarray:
    scale = cfg.d_model ** 0.5 if cfg.mlp_kind == "geglu" else 1.0
    if cfg.modality == "audio":
        toks = batch["tokens"]  # [B, S, K]
        emb = params["embed"]   # [K, V, d]
        x = sum(jnp.take(emb[i], toks[..., i], axis=0) for i in range(cfg.num_codebooks))
    elif cfg.modality == "vlm" and "img_embeds" in batch:
        txt = jnp.take(params["embed"], batch["tokens"], axis=0) * scale
        x = jnp.concatenate([batch["img_embeds"].astype(txt.dtype), txt], axis=1)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0) * scale
    return x.astype(COMPUTE_DTYPE)


def logits_from_hidden(cfg: ModelConfig, params, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        emb = params["embed"]
        if cfg.modality == "audio":
            out = jnp.einsum("bsd,kvd->bskv", h, emb.astype(h.dtype))
            return out
        return h @ emb.astype(h.dtype).T
    out = h @ params["lm_head"].astype(h.dtype)
    if cfg.modality == "audio":
        return out.reshape(out.shape[:-1] + (cfg.num_codebooks, cfg.vocab_size))
    return out


# ---------------------------------------------------------------------------
# Caches


def cache_cfg_for(cfg: ModelConfig, kind: str, policy: CompressionPolicy,
                  batch: int, capacity: int) -> cache_lib.CacheConfig:
    if kind == "local":
        return cache_lib.CacheConfig(
            batch=batch, kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
            capacity=min(capacity, cfg.local_window), policy=policy,
            kind="window", window=cfg.local_window)
    return cache_lib.CacheConfig(
        batch=batch, kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        capacity=capacity, policy=policy,
        kind="fp16" if policy.is_fp16 else "gear")


def _unit_cache(cfg: ModelConfig, kind: str, policy, batch, capacity, dtype,
                layout: str = "dense", pool_pages: int = 0):
    """Zero cache object for ONE layer of the given kind.

    ``layout="paged"`` puts GEAR-compressible attention layers into the
    pooled page layout (:class:`~repro.core.cache.PagedGEARLayerCache`,
    ``pool_pages`` pages).  Window ring buffers, fp16 caches, and RWKV/SSM
    recurrent state have no chunk decomposition and stay dense inside a
    mixed tree — the documented fallback (DESIGN.md §5).
    """
    if kind == "rwkv":
        return rwkv_lib.init_rwkv_state(cfg, batch, dtype)
    ccfg = cache_cfg_for(cfg, kind, policy, batch, capacity)
    if layout == "paged" and cache_lib.paged_supported(ccfg):
        c = cache_lib.init_paged_layer_cache(ccfg, pool_pages, dtype)
    else:
        c = cache_lib.init_layer_cache(ccfg, dtype)
    if cfg.ssm and cfg.hybrid_parallel:
        return (c, ssm_lib.init_ssm_state(cfg, batch, dtype))
    return c


def init_caches(cfg: ModelConfig, policy: CompressionPolicy, batch: int,
                capacity: int, dtype=jnp.bfloat16, layout: str = "dense",
                pool_pages: int = 0):
    """Tuple over pattern positions of caches stacked over repeats [R, ...].

    ``layout="paged"`` gives every paged-capable position a page pool leaf
    ``[R, pool_pages, ...]``: each repeat of each position has its own
    pool, all addressed by ONE engine-owned block table ``[B, C]`` (page
    id ``p`` means page ``p`` in every layer's pool — that is what makes
    the allocator a single global byte-budgeted pool).
    """
    if layout not in ("dense", "paged"):
        raise ValueError(f"layout must be dense/paged, got {layout!r}")
    if layout == "paged" and pool_pages < 2:
        raise ValueError("paged layout needs pool_pages >= 2 "
                         "(page 0 is the reserved zero page)")
    R = cfg.pattern_repeats
    out = []
    for kind in cfg.layer_pattern:
        one = _unit_cache(cfg, kind, policy, batch, capacity, dtype,
                          layout=layout, pool_pages=pool_pages)
        out.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape), one))
    return tuple(out)


# ---------------------------------------------------------------------------
# Blocks


def _apply_block_train(cfg: ModelConfig, bp, x, kind, positions, prefix_len,
                       q_chunk, want_kv: bool, attn_impl: str = "chunked"):
    """Returns (x, aux, cache_or_kv)."""
    if kind == "rwkv":
        h, (shift_tm, wkv) = rwkv_lib.time_mix_apply(cfg, bp, apply_norm(x, bp["ln1"], "layernorm"))
        x = x + h
        h, shift_cm = rwkv_lib.channel_mix_apply(cfg, bp, apply_norm(x, bp["ln2"], "layernorm"))
        x = x + h
        st = rwkv_lib.RWKVState(shift_tm=shift_tm.astype(jnp.bfloat16),
                                shift_cm=shift_cm.astype(jnp.bfloat16), wkv=wkv)
        return x, jnp.zeros((), jnp.float32), st if want_kv else None

    xin = apply_norm(x, bp["ln1"], cfg.norm)
    h, (k, v) = attn_lib.attention_train(cfg, bp["attn"], xin, positions, kind,
                                         prefix_len, q_chunk, impl=attn_impl)
    ssm_state = None
    if cfg.ssm and cfg.hybrid_parallel:
        h2, ssm_state = ssm_lib.ssm_apply(cfg, bp["ssm"], xin)
        h = (h + h2) * 0.5
    x = x + h
    xin2 = apply_norm(x, bp["ln2"], cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        m, aux = moe_apply(cfg, bp["moe"], xin2)
    else:
        m = mlp_apply(cfg, bp["mlp"], xin2)
    x = x + m
    kv_out = None
    if want_kv:
        kv_out = ((k, v), ssm_state) if ssm_state is not None else (k, v)
    return x, aux, kv_out


def _apply_block_decode(cfg: ModelConfig, bp, x_t, kind, pos, cache, policy,
                        batch, capacity, fused: str = "auto",
                        block_tables=None):
    if kind == "rwkv":
        h, cache = rwkv_lib.time_mix_decode(cfg, bp, apply_norm(x_t, bp["ln1"], "layernorm"), cache)
        x_t = x_t + h
        h, cache = rwkv_lib.channel_mix_decode(cfg, bp, apply_norm(x_t, bp["ln2"], "layernorm"), cache)
        return x_t + h, cache

    hybrid = cfg.ssm and cfg.hybrid_parallel
    attn_cache, ssm_state = (cache if hybrid else (cache, None))
    ccfg = cache_cfg_for(cfg, kind, policy, batch, capacity)
    xin = apply_norm(x_t, bp["ln1"], cfg.norm)
    h, attn_cache = attn_lib.attention_decode(cfg, bp["attn"], xin, pos, attn_cache,
                                              ccfg, kind, fused=fused,
                                              block_tables=block_tables)
    if hybrid:
        h2, ssm_state = ssm_lib.ssm_decode(cfg, bp["ssm"], xin, ssm_state)
        h = (h + h2) * 0.5
    x_t = x_t + h
    xin2 = apply_norm(x_t, bp["ln2"], cfg.norm)
    m = moe_apply(cfg, bp["moe"], xin2)[0] if cfg.moe else mlp_apply(cfg, bp["mlp"], xin2)
    x_t = x_t + m
    new_cache = (attn_cache, ssm_state) if hybrid else attn_cache
    return x_t, new_cache


def _apply_block_prefill(cfg: ModelConfig, bp, x, kind, positions, prefix_len,
                         q_chunk, policy, batch, capacity, cache_dtype,
                         fused: str, attn_impl: str, cache=None,
                         start_pos: int = 0, padded_tail: bool = False,
                         true_len=None):
    """Prefill block that builds its layer cache directly (streaming mode).

    Layers supporting the streaming pipeline project/attend/compress chunk
    by chunk (the full-sequence FP16 K/V never exists); window / softcap /
    prefix-LM / fp16 layers fall back to monolithic attention with the
    batched compression event, inside the same unit body.  Suffix prefill
    (``start_pos`` > 0, ``cache`` pre-populated with the cached prefix
    chunks) has no such fallback: every layer must take the streaming
    pipeline, since only it can see the prefix in compressed form.
    Returns (x, aux, cache)."""
    if kind == "rwkv":
        if start_pos or padded_tail:
            raise ValueError("suffix/bucketed prefill cannot resume an "
                             "RWKV state")
        return _apply_block_train(cfg, bp, x, kind, positions, prefix_len,
                                  q_chunk, want_kv=True)
    ccfg = cache_cfg_for(cfg, kind, policy, batch, capacity)
    if not attn_lib.streaming_prefill_supported(cfg, kind, ccfg):
        if start_pos or padded_tail:
            raise ValueError(
                f"suffix/bucketed prefill requires every layer to support "
                f"the streaming pipeline (kind={kind!r} does not)")
        x, aux, kv = _apply_block_train(cfg, bp, x, kind, positions, prefix_len,
                                        q_chunk, want_kv=True,
                                        attn_impl=attn_impl)
        return x, aux, _kv_to_cache(cfg, kind, kv, policy, batch, capacity,
                                    cache_dtype)
    xin = apply_norm(x, bp["ln1"], cfg.norm)
    h, cache = attn_lib.attention_prefill_streaming(
        cfg, bp["attn"], xin, positions, kind, ccfg, fused=fused,
        dtype=cache_dtype, cache=cache, start_pos=start_pos,
        padded_tail=padded_tail, true_len=true_len)
    ssm_state = None
    if cfg.ssm and cfg.hybrid_parallel:
        if start_pos or padded_tail:
            raise ValueError("suffix/bucketed prefill cannot resume a "
                             "hybrid SSM state")
        h2, ssm_state = ssm_lib.ssm_apply(cfg, bp["ssm"], xin)
        h = (h + h2) * 0.5
    x = x + h
    xin2 = apply_norm(x, bp["ln2"], cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        m, aux = moe_apply(cfg, bp["moe"], xin2)
    else:
        m = mlp_apply(cfg, bp["mlp"], xin2)
    x = x + m
    if ssm_state is not None:
        return x, aux, (cache, ssm_state)
    return x, aux, cache


def _kv_to_cache(cfg: ModelConfig, kind, kv, policy, batch, capacity, dtype):
    """Convert (k, v) from prefill attention into a filled layer cache."""
    if kind == "rwkv":
        return kv  # already an RWKVState
    if cfg.ssm and cfg.hybrid_parallel:
        (k, v), ssm_state = kv
    else:
        k, v = kv
    ccfg = cache_cfg_for(cfg, kind, policy, batch, capacity)
    c = cache_lib.init_layer_cache(ccfg, dtype)
    c = cache_lib.prefill_layer_cache(ccfg, c, k, v)
    if cfg.ssm and cfg.hybrid_parallel:
        return (c, ssm_state)
    return c


# ---------------------------------------------------------------------------
# Full forward passes


def forward(cfg: ModelConfig, params, batch: dict, mode: str = "train",
            policy: CompressionPolicy | None = None, capacity: int = 0,
            remat: bool = False, remat_policy: str = "full",
            q_chunk_target: int = 512, cache_dtype=jnp.bfloat16,
            unroll_layers: bool = False, prefill_mode: str = "monolithic",
            fused: str = "auto", start_pos: int = 0, init_caches=None,
            padded_tail: bool = False, true_len=None):
    """Full-sequence forward.

    mode="train": returns (logits, aux_loss)
    mode="prefill": returns (logits_last [B, 1, vocab...], caches, aux)

    ``start_pos`` > 0 is the **suffix-offset prefill entry** (prefix
    cache): ``batch`` holds only the tokens after a chunk-aligned cached
    prefix, ``init_caches`` is the cache tree with the prefix chunks
    already spliced in, positions are offset by ``start_pos``, and every
    layer runs the streaming pipeline over the suffix with the cached
    chunks visible as compressed history.  Requires
    ``prefill_mode="streaming"`` and a model whose every layer supports it.

    ``padded_tail`` / ``true_len`` are the length-bucketing hooks (same
    streaming-only requirement): the batch is right-padded to a chunk
    multiple, the last chunk-width block stays out of compression (it lands
    in the FP16 streaming buffer), cache lengths are set from the traced
    ``true_len``, and the prefill logits come from position ``true_len - 1``
    instead of the last row.

    ``prefill_mode`` selects the prefill pipeline: "monolithic" (full-seq
    attention, then one batched compression event per layer) or "streaming"
    (chunked compress-as-you-go — the FP16 K/V history is never
    materialized; unsupported layers fall back per
    :func:`repro.models.attention.streaming_prefill_supported`).  ``fused``
    picks the kernel path for prefill ("auto" = Pallas on TPU / oracles
    elsewhere, "interpret" forces the kernels, "off" = portable XLA) —
    monolithic prefill routes full-sequence attention through the
    ``flash_prefill`` kernel under the same knob.

    ``unroll_layers`` fully unrolls the layer-stack scan.  Needed inside
    (partially) manual ``shard_map`` regions, where XLA's SPMD partitioner
    cannot handle while loops (the PowerSGD train step); everywhere else
    the scan keeps compile time O(pattern).
    """
    x = embed_tokens(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(start_pos, start_pos + S, dtype=jnp.int32)
    prefix_len = cfg.num_prefix_tokens if cfg.modality == "vlm" else 0
    q_chunk = pick_q_chunk(S, q_chunk_target)
    want_kv = mode == "prefill"
    if start_pos and not (want_kv and prefill_mode == "streaming"):
        raise ValueError("start_pos > 0 requires prefill_mode='streaming'")
    if padded_tail and not (want_kv and prefill_mode == "streaming"):
        raise ValueError("padded_tail requires prefill_mode='streaming'")
    attn_impl = "chunked"
    if want_kv and fused == "interpret":
        attn_impl = "flash-interpret"
    elif want_kv and fused == "auto" and kernel_ops.on_tpu():
        attn_impl = "flash"

    if want_kv and prefill_mode == "streaming":
        def unit_body_stream(carry, xs):
            unit_params, unit_caches = xs if init_caches is not None else (xs, None)
            x, aux = carry
            caches = []
            for i, kind in enumerate(cfg.layer_pattern):
                x, a, c = _apply_block_prefill(
                    cfg, unit_params[i], x, kind, positions, prefix_len,
                    q_chunk, policy, B, capacity, cache_dtype, fused,
                    attn_impl,
                    cache=None if unit_caches is None else unit_caches[i],
                    start_pos=start_pos, padded_tail=padded_tail,
                    true_len=true_len)
                aux = aux + a
                caches.append(c)
            return (x, aux), tuple(caches)

        scan_xs = (params["blocks"] if init_caches is None
                   else (params["blocks"], init_caches))
        (x, aux), caches = jax.lax.scan(
            unit_body_stream, (x, jnp.zeros((), jnp.float32)), scan_xs,
            unroll=cfg.pattern_repeats if unroll_layers else 1)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        if true_len is not None:
            # Bucketed prefill: the last REAL token of this call's input
            # sits at row true_len - 1 (traced), not at the padded S - 1.
            last = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(true_len, jnp.int32) - 1, 1, axis=1)
        else:
            last = x[:, -1:, :]
        logits = logits_from_hidden(cfg, params, last)
        return logits, tuple(caches), aux

    def unit_body(carry, unit_params):
        x, aux = carry
        kvs = []
        for i, kind in enumerate(cfg.layer_pattern):
            x, a, kv = _apply_block_train(cfg, unit_params[i], x, kind, positions,
                                          prefix_len, q_chunk, want_kv,
                                          attn_impl=attn_impl)
            aux = aux + a
            if want_kv:
                kvs.append(kv)
        return (x, aux), tuple(kvs) if want_kv else None

    if remat and not want_kv:
        ckpt_policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                       if remat_policy == "dots" else None)
        body = jax.checkpoint(unit_body, policy=ckpt_policy)
    else:
        body = unit_body
    (x, aux), kv_stacks = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       params["blocks"],
                                       unroll=cfg.pattern_repeats if unroll_layers else 1)
    x = apply_norm(x, params["final_norm"], cfg.norm)

    if mode == "train":
        logits = logits_from_hidden(cfg, params, x)
        return logits, aux

    # prefill: convert stacked (k, v) into caches, logits for last position only
    caches = []
    for i, kind in enumerate(cfg.layer_pattern):
        conv = functools.partial(_kv_to_cache, cfg, kind, policy=policy, batch=B,
                                 capacity=capacity, dtype=cache_dtype)
        caches.append(jax.lax.map(conv, kv_stacks[i]))
    logits = logits_from_hidden(cfg, params, x[:, -1:, :])
    return logits, tuple(caches), aux


def decode_tokens(cfg: ModelConfig, params, token_batch: dict, caches,
                  pos, policy: CompressionPolicy, capacity: int,
                  fused: str = "auto", block_tables=None):
    """One decode step.  token_batch: {"tokens": [B, 1(...)]}.

    ``pos`` is a scalar int32 or a per-slot ``[B]`` vector (continuous
    batching: each batch row decodes at its own absolute position and its
    layer caches advance at their own per-slot lengths).  ``fused`` selects
    the GEAR attend path (see :func:`repro.models.attention.attention_decode`).
    ``block_tables [B, C]`` is required when ``caches`` holds paged layers
    (one table addresses every layer's pool); layers that stayed dense in a
    mixed tree ignore it.  Returns (logits [B, 1, ...], new caches)."""
    x = embed_tokens(cfg, params, token_batch)
    B = x.shape[0]

    def unit_body(x, xs):
        unit_params, unit_caches = xs
        new_caches = []
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc = _apply_block_decode(cfg, unit_params[i], x, kind, pos,
                                        unit_caches[i], policy, B, capacity,
                                        fused=fused,
                                        block_tables=block_tables)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(unit_body, x, (params["blocks"], caches))
    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = logits_from_hidden(cfg, params, x)
    return logits, new_caches
