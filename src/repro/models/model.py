"""Public model facade: init / loss / prefill / decode + input specs."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import CompressionPolicy, GEAR_DEFAULT
from repro.models import transformer as tfm

__all__ = ["Model", "build_model", "input_specs", "decode_state_specs"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        return tfm.init_params(self.cfg, key)

    def init_abstract(self) -> Any:
        return jax.eval_shape(lambda: tfm.init_params(self.cfg, jax.random.PRNGKey(0)))

    # -- training ------------------------------------------------------------
    def loss_fn(self, params, batch: dict, remat: bool = False,
                remat_policy: str = "full", unroll_layers: bool = False):
        """Next-token cross-entropy.  Returns (loss, metrics)."""
        cfg = self.cfg
        logits, aux = tfm.forward(cfg, params, batch, mode="train", remat=remat,
                                  remat_policy=remat_policy,
                                  unroll_layers=unroll_layers)
        if cfg.modality == "audio":
            labels = batch["tokens"][:, 1:, :]                  # [B, S-1, K]
            lg = logits[:, :-1]                                 # [B, S-1, K, V]
            ce = _xent(lg, labels)
        elif cfg.modality == "vlm":
            p = cfg.num_prefix_tokens
            labels = batch["tokens"][:, 1:]                     # text tokens only
            lg = logits[:, p:-1]
            ce = _xent(lg, labels)
        else:
            labels = batch["tokens"][:, 1:]
            lg = logits[:, :-1]
            ce = _xent(lg, labels)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # -- serving ------------------------------------------------------------
    def prefill(self, params, batch: dict, policy: CompressionPolicy,
                capacity: int, prefill_mode: str = "monolithic",
                fused: str = "auto", padded_tail: bool = False,
                true_len=None):
        """Full-prompt forward producing per-layer caches.

        Works for any batch size; the serving engine also calls it at
        batch=1 to build a single request's cache for slot splicing
        (:meth:`repro.serving.engine.Engine.prefill_slot`).

        ``prefill_mode``: "monolithic" (full-sequence attention, then one
        batched compression event per layer) or "streaming" (chunked
        compress-as-you-go: O(compressed cache + one chunk) peak memory,
        history attended in compressed form — decode semantics).  Both
        modes produce bit-identical caches.  ``fused`` picks the prefill
        kernel path ("auto"/"interpret"/"off"), mirroring decode's knob.

        ``padded_tail=True`` (streaming only, with ``true_len`` the traced
        count of real tokens) is the mixed-length bucketing entry: the
        batch is right-padded to a chunk multiple, pad tokens never reach
        compressed storage, cache lengths and the returned logits reflect
        the true length (see :func:`repro.models.transformer.forward`).
        """
        logits, caches, _ = tfm.forward(self.cfg, params, batch, mode="prefill",
                                        policy=policy, capacity=capacity,
                                        prefill_mode=prefill_mode, fused=fused,
                                        padded_tail=padded_tail,
                                        true_len=true_len)
        return logits, caches

    def prefill_suffix(self, params, batch: dict, caches, start_pos: int,
                       policy: CompressionPolicy, capacity: int,
                       fused: str = "auto", padded_tail: bool = False,
                       true_len=None):
        """Suffix-offset prefill over a cache holding a chunk-aligned prefix.

        ``batch`` covers only the tokens after the cached prefix;
        ``caches`` is a cache tree whose first ``start_pos / n_b`` chunks
        were spliced from the prefix cache
        (:func:`repro.core.cache.splice_prefix_chunks`).  Runs the
        streaming pipeline on the suffix with the prefix visible as
        compressed history — the engine's prefix-cache hit path
        (:meth:`repro.serving.engine.Engine.prefill_slot`); the resulting
        cache and last-position logits are bit-identical to a cold prefill
        of prefix + suffix (DESIGN.md §4).  Returns (logits, caches).

        ``padded_tail`` / ``true_len`` bucket a mixed-length suffix the
        same way :meth:`prefill` does — ``true_len`` counts the real
        tokens of THIS call's (suffix) batch, not prefix + suffix.
        """
        logits, caches, _ = tfm.forward(self.cfg, params, batch, mode="prefill",
                                        policy=policy, capacity=capacity,
                                        prefill_mode="streaming", fused=fused,
                                        start_pos=start_pos, init_caches=caches,
                                        padded_tail=padded_tail,
                                        true_len=true_len)
        return logits, caches

    def decode_step(self, params, token_batch: dict, caches, pos,
                    policy: CompressionPolicy, capacity: int,
                    fused: str = "auto", block_tables=None):
        """One decode step.  ``pos`` is a scalar (all slots aligned) or a
        per-slot ``[B]`` vector of absolute positions (continuous batching).
        ``fused``: GEAR attend path — "auto" (fused kernel where the layout
        supports it, ragged-aware), "interpret" (force the Pallas kernel in
        interpret mode), or "off" (portable jnp attend).  ``block_tables``
        is required when ``caches`` was built with ``layout="paged"``."""
        return tfm.decode_tokens(self.cfg, params, token_batch, caches, pos,
                                 policy, capacity, fused=fused,
                                 block_tables=block_tables)

    def init_caches(self, policy: CompressionPolicy, batch: int, capacity: int,
                    layout: str = "dense", pool_pages: int = 0):
        return tfm.init_caches(self.cfg, policy, batch, capacity,
                               layout=layout, pool_pages=pool_pages)


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Cross entropy without materializing f32 logits: the max/exp/sum chain
    runs elementwise-fused over the bf16 logits with f32 accumulation."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    ex = jnp.exp((logits - m).astype(jnp.float32))
    lse = jnp.log(jnp.sum(ex, axis=-1)) + m[..., 0].astype(jnp.float32)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll.astype(jnp.float32))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell.

    Training/prefill: the token batch.  Decode: one new token (the cache
    specs come from :func:`decode_state_specs`).  Modality frontends are
    stubs: VLM gets precomputed SigLIP patch embeddings, audio gets EnCodec
    codebook token frames.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.mode in ("train", "prefill"):
        if cfg.modality == "vlm":
            p = cfg.num_prefix_tokens
            return {
                "tokens": sds((B, S - p), i32),
                "img_embeds": sds((B, p, cfg.d_model), jnp.bfloat16),
            }
        if cfg.modality == "audio":
            return {"tokens": sds((B, S, cfg.num_codebooks), i32)}
        return {"tokens": sds((B, S), i32)}
    # decode: one token; the S-length cache is a separate argument
    if cfg.modality == "audio":
        return {"tokens": sds((B, 1, cfg.num_codebooks), i32)}
    return {"tokens": sds((B, 1), i32)}


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig,
                       policy: CompressionPolicy = GEAR_DEFAULT):
    """Abstract cache pytree for a decode cell (no allocation)."""
    capacity = _round_capacity(shape.seq_len, policy)
    return jax.eval_shape(
        lambda: tfm.init_caches(cfg, policy, shape.global_batch, capacity))


def _round_capacity(seq_len: int, policy: CompressionPolicy) -> int:
    nb = policy.buffer_size
    return (seq_len + nb - 1) // nb * nb
