"""Selective SSM branch (Hymba's Mamba heads), in Mamba-2/SSD head form.

Adaptation note (DESIGN.md §Hardware adaptation): Mamba-1's per-(channel,
state) decay does not map onto MXU-friendly chunked matmuls; we use the
Mamba-2 SSD parameterization — scalar per-head data-dependent decay
``a_t = exp(−Δ_t·exp(A_h))`` with per-head B/C of width ``ssm_state`` — which
is exactly the form the shared chunked engine (:mod:`linear_scan`) computes.
Hymba pairs these SSM heads with attention heads in parallel inside each
block (see transformer.py).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init
from repro.models import linear_scan

__all__ = ["SSMState", "ssm_params", "ssm_apply", "ssm_decode", "init_ssm_state"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["conv", "state"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class SSMState:
    conv: jnp.ndarray    # [B, conv_w-1, d_inner] rolling conv inputs
    state: jnp.ndarray   # [B, H, ssm_state, head_dim]


def _dims(cfg: ModelConfig):
    H, dh = cfg.num_heads, cfg.head_dim
    return H, dh, H * dh, cfg.ssm_state


def ssm_params(cfg: ModelConfig, kg: KeyGen) -> dict:
    H, dh, dinner, ds = _dims(cfg)
    d = cfg.d_model
    return {
        "w_in": dense_init(kg(), (d, 2 * dinner)),              # x branch + gate z
        "conv_w": dense_init(kg(), (cfg.ssm_conv, dinner), fan_in=cfg.ssm_conv),
        "w_bcdt": dense_init(kg(), (dinner, H * (2 * ds + 1))),
        "a_log": jnp.zeros((H,), jnp.float32),                  # exp(a_log)=1 decay rate
        "dt_bias": jnp.full((H,), -1.0, jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "w_out": dense_init(kg(), (dinner, d), fan_in=dinner),
    }


def _conv_train(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv along S.  x: [B, S, dinner]; w: [cw, dinner]."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(cw))
    return out


def _bcdt(cfg: ModelConfig, params, xc: jnp.ndarray):
    """xc: [..., dinner] -> (B̃ [..., H, ds], C̃ [..., H, ds], log_w [..., H])."""
    H, dh, dinner, ds = _dims(cfg)
    proj = xc @ params["w_bcdt"].astype(xc.dtype)
    proj = proj.reshape(proj.shape[:-1] + (H, 2 * ds + 1)).astype(jnp.float32)
    b, c, dt_raw = proj[..., :ds], proj[..., ds:2 * ds], proj[..., -1]
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])
    log_w = -dt * jnp.exp(params["a_log"])
    return b, c, dt, log_w


def ssm_apply(cfg: ModelConfig, params, x: jnp.ndarray, chunk: int = 64):
    """Train/prefill path.  x: [B, S, d] -> (y [B, S, d], final SSMState)."""
    H, dh, dinner, ds = _dims(cfg)
    B, S, _ = x.shape
    xz = x @ params["w_in"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_train(xi, params["conv_w"].astype(x.dtype)))
    b, c, dt, log_w = _bcdt(cfg, params, xc)

    v = xc.reshape(B, S, H, dh).swapaxes(1, 2)                    # [B,H,S,dh]
    r = c.swapaxes(1, 2)                                          # [B,H,S,ds]
    kk = (b * dt[..., None]).swapaxes(1, 2)                       # Δ folded into k
    lw = log_w.swapaxes(1, 2)[..., None]                          # [B,H,S,1]
    eff_chunk = min(chunk, S) if S % min(chunk, S) == 0 else S
    y, stateT = linear_scan.chunked_scan(r, kk, v.astype(jnp.float32), lw,
                                         chunk=eff_chunk, mode="inclusive")
    y = y + params["d_skip"][None, :, None, None] * v.astype(jnp.float32)
    y = y.swapaxes(1, 2).reshape(B, S, dinner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["w_out"].astype(x.dtype)
    conv_tail = xi[:, max(0, S - (cfg.ssm_conv - 1)):, :]
    if conv_tail.shape[1] < cfg.ssm_conv - 1:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (cfg.ssm_conv - 1 - conv_tail.shape[1], 0), (0, 0)))
    return out, SSMState(conv=conv_tail, state=stateT)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    H, dh, dinner, ds = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, dinner), dtype),
        state=jnp.zeros((batch, H, ds, dh), jnp.float32),
    )


def ssm_decode(cfg: ModelConfig, params, x_t: jnp.ndarray, st: SSMState):
    """One-token step.  x_t: [B, 1, d] -> (y [B, 1, d], new state)."""
    H, dh, dinner, ds = _dims(cfg)
    B = x_t.shape[0]
    xz = x_t[:, 0] @ params["w_in"].astype(x_t.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)                             # [B, dinner]
    window = jnp.concatenate([st.conv, xi[:, None, :]], axis=1)   # [B, cw, dinner]
    xc = jax.nn.silu(jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                                params["conv_w"].astype(jnp.float32))).astype(x_t.dtype)
    b, c, dt, log_w = _bcdt(cfg, params, xc)
    v = xc.reshape(B, H, dh)
    kk = b * dt[..., None]
    y, state = linear_scan.decode_step(c, kk, v.astype(jnp.float32),
                                       log_w[..., None], st.state, mode="inclusive")
    y = y + params["d_skip"][None, :, None] * v.astype(jnp.float32)
    y = (y.reshape(B, dinner) * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    out = (y @ params["w_out"].astype(x_t.dtype))[:, None, :]
    return out, SSMState(conv=window[:, 1:, :], state=state)
