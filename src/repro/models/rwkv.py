"""RWKV6 "Finch" block: data-dependent-decay linear recurrence, attention-free.

Faithful structure per arXiv:2404.05892: data-dependent token-shift (ddlerp
with a 5-way LoRA), data-dependent decay ``w_t = exp(-exp(w0 + LoRA(x)))``,
bonus ``u`` for the current token, per-head GroupNorm on the recurrence
output, silu-gated output projection, and squared-ReLU channel mix.  The
recurrence itself runs through the shared chunked engine in "bonus" mode:

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ),   S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

GEAR applicability: none — there is no KV cache (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init
from repro.models import linear_scan

__all__ = ["RWKVState", "rwkv_params", "time_mix_apply", "channel_mix_apply",
           "time_mix_decode", "channel_mix_decode", "init_rwkv_state"]

LORA_MIX = 32
LORA_DECAY = 64


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["shift_tm", "shift_cm", "wkv"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class RWKVState:
    shift_tm: jnp.ndarray   # [B, d] previous token input (time mix)
    shift_cm: jnp.ndarray   # [B, d] previous token input (channel mix)
    wkv: jnp.ndarray        # [B, H, Dk, Dv] recurrence state


def _heads(cfg: ModelConfig):
    return cfg.num_heads, cfg.head_dim


def rwkv_params(cfg: ModelConfig, kg: KeyGen) -> dict:
    d = cfg.d_model
    H, dh = _heads(cfg)
    return {
        "tm": {
            "mix_base": 0.5 * jnp.ones((5, d), jnp.float32),   # r,k,v,w,g static mixes
            "mix_lora_a": dense_init(kg(), (d, 5 * LORA_MIX)),
            "mix_lora_b": dense_init(kg(), (5, LORA_MIX, d), fan_in=LORA_MIX),
            "w0": jnp.full((d,), -2.0, jnp.float32),           # decay base (pre -exp(exp))
            "decay_lora_a": dense_init(kg(), (d, LORA_DECAY)),
            "decay_lora_b": dense_init(kg(), (LORA_DECAY, d), fan_in=LORA_DECAY),
            "u": jnp.zeros((H, dh), jnp.float32),              # bonus
            "wr": dense_init(kg(), (d, d)),
            "wk": dense_init(kg(), (d, d)),
            "wv": dense_init(kg(), (d, d)),
            "wg": dense_init(kg(), (d, d)),
            "wo": dense_init(kg(), (d, d)),
            "ln_scale": jnp.ones((d,), jnp.float32),           # per-head groupnorm
            "ln_bias": jnp.zeros((d,), jnp.float32),
        },
        "cm": {
            "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
            "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
            "wk": dense_init(kg(), (d, cfg.d_ff)),
            "wv": dense_init(kg(), (cfg.d_ff, d), fan_in=cfg.d_ff),
            "wr": dense_init(kg(), (d, d)),
        },
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token shift -> the 5 mixed inputs [5, B, S, d]."""
    dx = x_prev - x
    base = p["mix_base"].astype(x.dtype)
    xx = x + dx * base[0][None, None, :]           # coarse mix for the lora input
    lora = jnp.tanh(xx @ p["mix_lora_a"].astype(x.dtype))
    lora = lora.reshape(lora.shape[:-1] + (5, LORA_MIX))
    dyn = jnp.einsum("bsfl,fld->fbsd", lora, p["mix_lora_b"].astype(x.dtype))
    mixes = base[:, None, None, :] + dyn                          # [5,B,S,d]
    return x[None] + dx[None] * mixes


def _group_norm_heads(x, scale, bias, H, eps=64e-5):
    """Per-head LayerNorm (RWKV's GroupNorm(H)).  x: [B, S, d]."""
    B, S, d = x.shape
    xh = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xn = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xn.reshape(B, S, d) * scale + bias).astype(x.dtype)


def time_mix_apply(cfg: ModelConfig, params, x: jnp.ndarray,
                   state: RWKVState | None = None, chunk: int = 64):
    """x: [B, S, d] -> (y, (shift_carry [B,d], wkv state))."""
    p = params["tm"]
    H, dh = _heads(cfg)
    B, S, d = x.shape
    x_prev = jnp.concatenate(
        [state.shift_tm[:, None, :] if state is not None else jnp.zeros((B, 1, d), x.dtype),
         x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, dh).swapaxes(1, 2)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, dh).swapaxes(1, 2)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, dh).swapaxes(1, 2)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    dec = p["w0"] + jnp.tanh(xw @ p["decay_lora_a"].astype(x.dtype)) @ p["decay_lora_b"].astype(x.dtype)
    log_w = -jnp.exp(dec.astype(jnp.float32))                     # ≤ 0
    log_w = log_w.reshape(B, S, H, dh).swapaxes(1, 2)
    s0 = state.wkv if state is not None else None
    eff_chunk = chunk if S % chunk == 0 else S
    y, wkv = linear_scan.chunked_scan(r, k, v, log_w, chunk=eff_chunk,
                                      u=p["u"], state0=s0, mode="bonus")
    y = y.swapaxes(1, 2).reshape(B, S, d)
    y = _group_norm_heads(y, p["ln_scale"], p["ln_bias"], H)
    out = (y * g) @ p["wo"].astype(x.dtype)
    return out, (x[:, -1, :], wkv)


def channel_mix_apply(cfg: ModelConfig, params, x: jnp.ndarray,
                      state: RWKVState | None = None):
    p = params["cm"]
    B, S, d = x.shape
    x_prev = jnp.concatenate(
        [state.shift_cm[:, None, :] if state is not None else jnp.zeros((B, 1, d), x.dtype),
         x[:, :-1, :]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["mix_k"].astype(x.dtype)
    xr = x + dx * p["mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * (kk @ p["wv"].astype(x.dtype))
    return out, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> RWKVState:
    H, dh = _heads(cfg)
    return RWKVState(
        shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, H, dh, dh), jnp.float32),
    )


def time_mix_decode(cfg: ModelConfig, params, x_t: jnp.ndarray, state: RWKVState):
    """x_t: [B, 1, d].  Single-token step via the same code path (S=1)."""
    out, (shift, wkv) = time_mix_apply(cfg, params, x_t, state=state, chunk=1)
    return out, dataclasses.replace(state, shift_tm=shift, wkv=wkv)


def channel_mix_decode(cfg: ModelConfig, params, x_t: jnp.ndarray, state: RWKVState):
    out, shift = channel_mix_apply(cfg, params, x_t, state=state)
    return out, dataclasses.replace(state, shift_cm=shift)
