"""Serving engine: prefill + GEAR-cached decode, sharded over the mesh.

The engine owns the jitted prefill/decode programs (cache donated across
steps so decode is allocation-free), token sampling, and the byte-level
cache accounting the memory benchmarks read.  Two batching modes sit on
top (:mod:`repro.serving.scheduler`):

* wave mode — :meth:`Engine.generate` drives the whole batch in lockstep;
* continuous mode — the scheduler drives :meth:`Engine.decode` one step at
  a time with per-slot position vectors, and :meth:`Engine.prefill_slot`
  splices a fresh request's batch-1 cache into a live batch slot (the cache
  tree is donated, so the splice is an in-place batch-row write).

Two cache layouts (:class:`CacheLayout`):

* ``DENSE`` — every slot owns full-capacity per-slot arrays; admission is
  slot-count-limited.
* ``PAGED`` — compressed chunks live in a global pool of fixed-size pages
  addressed through per-slot block tables (DESIGN.md §5,
  :mod:`repro.serving.pagedpool`); admission is pool-bytes-limited, a
  request reserves only the pages its own lifetime needs, and prefix-cache
  hits share pages by refcount instead of copying.  Decode gathers pages
  by table index inside the fused kernel grid
  (:func:`repro.kernels.gear_decode.gear_decode_paged`), and the layout is
  bit-identical to the dense slot cache under the zero-page invariant.
"""

from __future__ import annotations

import contextlib
import dataclasses
import enum
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core.policy import FP16, CompressionPolicy
from repro.dist import sharding as shd
from repro.kernels import ops as kernel_ops
from repro.models import attention as attn_lib
from repro.models.model import Model
from repro.models.transformer import cache_cfg_for
from repro.obs import Observability, ObsConfig
from repro.obs.fidelity import FidelityProbe
from repro.obs.tracing import profiler_span
from repro.prefixcache import PrefixCache
from repro.prefixcache import store as pc_store
from repro.serving.pagedpool import PagePool, PagePoolStore, pages_needed
from repro.serving.sampling import sample

__all__ = ["AttendPath", "PrefillMode", "CacheLayout", "EngineConfig",
           "Engine", "prefix_cache_unsupported_reason"]


class AttendPath(str, enum.Enum):
    """GEAR decode/prefill attend kernel path.

    ``AUTO`` — fused gear_attend where the cache layout supports it (Pallas
    kernel on TPU, jnp oracle elsewhere; ragged-aware, so continuous
    batching takes it too).  ``INTERPRET`` — force the Pallas kernel in
    interpret mode (CI kernel lane).  ``OFF`` — portable jnp attend.
    """
    AUTO = "auto"
    INTERPRET = "interpret"
    OFF = "off"

    __str__ = str.__str__


class PrefillMode(str, enum.Enum):
    """Prefill pipeline: ``MONOLITHIC`` (full-sequence attention, one
    batched compression event per layer) or ``STREAMING`` (chunked
    compress-as-you-go — O(compressed cache + one chunk) peak memory).
    Both build bit-identical caches."""
    MONOLITHIC = "monolithic"
    STREAMING = "streaming"

    __str__ = str.__str__


class CacheLayout(str, enum.Enum):
    """Serving cache layout: ``DENSE`` per-slot arrays or ``PAGED`` pooled
    compressed-chunk pages behind per-slot block tables (DESIGN.md §5)."""
    DENSE = "dense"
    PAGED = "paged"

    __str__ = str.__str__


def _coerce(cls, value, knob: str, options: str):
    """Enum coercion that keeps the legacy stringly error text, so existing
    callers matching on e.g. ``"prefill_mode must be"`` keep passing."""
    try:
        return cls(value)
    except ValueError:
        raise ValueError(f"{knob} must be {options}, got {value!r}") from None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch: int
    capacity: int                  # max total tokens per sequence
    policy: CompressionPolicy
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1               # -1: never stop early
    # GEAR decode-attend path (:class:`AttendPath`).  Plain strings
    # ("auto"/"interpret"/"off") are coerced for back-compat.  The same
    # knob selects the prefill kernel path (flash_prefill for monolithic
    # attention, gear_compress/gear_attend_block for streaming).
    fused: AttendPath = AttendPath.AUTO
    # Prefill pipeline (:class:`PrefillMode`); strings are coerced.
    prefill_mode: PrefillMode = PrefillMode.MONOLITHIC
    # Cross-request prefix cache (radix trie over compressed GEAR chunks,
    # repro.prefixcache): prefill_slot splices the longest cached
    # chunk-aligned prompt prefix into the slot and streams only the
    # suffix — bit-identical caches/logits vs a cold prefill.  Requires
    # prefill_mode="streaming" (the hit path attends the cached prefix in
    # compressed form, which is exactly streaming's numeric model) and a
    # model whose every layer supports the streaming pipeline.  Under the
    # PAGED layout the trie's payloads are pool page ids, so a hit is a
    # refcount bump — no chunk bytes are ever copied.
    prefix_cache: bool = False
    prefix_cache_bytes: int = 256 << 20   # trie eviction byte budget
    # Trie lifecycle: ``prefix_cache_ttl`` seconds a cached chunk stays
    # valid from insert (0 = never expires; hits do not refresh it) and
    # the budget-pressure victim policy ("lru" recency / "lfu" use count).
    # Weight swaps invalidate independently of both: Engine.set_params
    # bumps a version tag that makes every cached chunk stale at once.
    prefix_cache_ttl: float = 0.0
    prefix_cache_eviction: str = "lru"
    # Numeric quarantine: guard every request's freshly closed compressed
    # chunks against NaN/Inf before they are spliced into the shared batch
    # tree or inserted into the prefix trie.  A poisoned prefill raises
    # :class:`~repro.core.cache.NumericFault` with the shared state
    # untouched — the scheduler fails that one request (FAILED status,
    # slot reset, pages released) while co-batched slots continue
    # bit-identically.  One fused all-finite reduction over the batch-1
    # tree per prefill; set False to shave it off a trusted pipeline.
    numeric_guard: bool = True
    # Cache layout (:class:`CacheLayout`); strings are coerced.  PAGED puts
    # every GEAR-compressible attention layer's closed chunks into a global
    # page pool; window/fp16/RWKV/SSM state stays dense inside the tree.
    layout: CacheLayout = CacheLayout.DENSE
    # PAGED pool sizing — set at most one.  ``pool_pages`` is the pool's
    # page-axis length (including reserved zero page 0, matching
    # ``init_caches(..., pool_pages=...)``); ``pool_bytes`` sizes the pool
    # to a device byte budget (pages = pool_bytes // page_bytes).  Default
    # (both 0): batch * n_chunks allocatable pages — the dense-equivalent
    # worst case, useful for parity testing rather than memory savings.
    pool_pages: int = 0
    pool_bytes: int = 0
    # Observability (:class:`repro.obs.ObsConfig`): metrics registry,
    # per-request tracing, and online compression-fidelity probes.  None
    # (default) builds no telemetry state and adds zero work to the hot
    # path; ``obs=True`` coerces to ``ObsConfig()`` defaults.  See
    # docs/observability.md.
    obs: ObsConfig | None = None

    def __post_init__(self):
        if self.obs is not None and not isinstance(self.obs, ObsConfig):
            if isinstance(self.obs, bool):
                object.__setattr__(self, "obs",
                                   ObsConfig() if self.obs else None)
            elif isinstance(self.obs, dict):
                object.__setattr__(self, "obs", ObsConfig(**self.obs))
            else:
                raise ValueError(
                    f"obs must be an ObsConfig, bool, or dict, got "
                    f"{self.obs!r}")
        object.__setattr__(self, "fused", _coerce(
            AttendPath, self.fused, "fused", "auto/interpret/off"))
        object.__setattr__(self, "prefill_mode", _coerce(
            PrefillMode, self.prefill_mode, "prefill_mode",
            "monolithic/streaming"))
        object.__setattr__(self, "layout", _coerce(
            CacheLayout, self.layout, "layout", "dense/paged"))
        if self.prefix_cache and self.prefill_mode is not PrefillMode.STREAMING:
            raise ValueError(
                "prefix_cache requires prefill_mode='streaming': the hit "
                "path attends the cached prefix in compressed form, so only "
                "streaming cold prefills are bit-identical to warm ones")
        if self.prefix_cache_eviction not in ("lru", "lfu"):
            raise ValueError(
                "prefix_cache_eviction must be 'lru' or 'lfu', got "
                f"{self.prefix_cache_eviction!r}")
        if self.prefix_cache_ttl < 0:
            raise ValueError(
                f"prefix_cache_ttl must be >= 0, got {self.prefix_cache_ttl}")
        if ((self.prefix_cache_ttl or self.prefix_cache_eviction != "lru")
                and not self.prefix_cache):
            raise ValueError(
                "prefix_cache_ttl / prefix_cache_eviction require "
                "prefix_cache=True")
        if self.pool_pages and self.pool_bytes:
            raise ValueError("set pool_pages OR pool_bytes, not both")
        if self.layout is CacheLayout.DENSE and (self.pool_pages or self.pool_bytes):
            raise ValueError("pool_pages/pool_bytes only apply to layout='paged'")


def prefix_cache_unsupported_reason(cfg, policy: CompressionPolicy,
                                    capacity: int) -> str | None:
    """Why this model/policy cannot take the prefix cache (None = it can).

    The hit path replays a cached chunk-aligned prefix as compressed
    history under the streaming suffix pipeline, so every layer must (a)
    keep all its prefill state in spliceable GEAR chunks and (b) support
    streaming prefill.  RWKV / hybrid-SSM recurrent states and the VLM
    bidirectional image prefix are neither; fp16 policies have no
    compressed chunks to cache.
    """
    if policy.is_fp16:
        return "fp16 policy has no compressed chunks to cache"
    if cfg.modality != "text":
        return f"modality {cfg.modality!r} (prompt is not a flat token-id sequence)"
    if cfg.ssm and cfg.hybrid_parallel:
        return "hybrid SSM state is not chunk-decomposable"
    for kind in cfg.layer_pattern:
        if kind == "rwkv":
            return "rwkv layers carry recurrent state, not spliceable chunks"
        ccfg = cache_cfg_for(cfg, kind, policy, 1, capacity)
        if not attn_lib.streaming_prefill_supported(cfg, kind, ccfg):
            return (f"layer kind {kind!r} does not support the streaming "
                    "prefill pipeline")
    return None


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig, mesh=None,
                 clock=None):
        self.model = model
        self.cfg = model.cfg
        self.ecfg = ecfg
        self.mesh = mesh
        self.layout = ecfg.layout
        # injectable monotonic clock shared with the prefix cache's TTL
        # logic (tests drive a FakeClock); None = real time
        self._clock = clock
        # chaos hook (serving/faults.py); attach_faults wires it + the pool
        self._faults = None
        self._finite_fn = jax.jit(cache_lib.tree_finite)
        cap = self._cap()
        # telemetry hub (repro.obs): the scheduler discovers it via
        # `engine.obs`; None when the knob is off (zero hot-path work)
        self.obs = (Observability(ecfg.obs, clock=clock)
                    if ecfg.obs is not None else None)

        if mesh is not None:
            if self.layout is CacheLayout.PAGED:
                raise NotImplementedError(
                    "paged layout is single-host for now: the block tables "
                    "are engine-owned host state (ROADMAP: sharded pool)")
            cache_abs = jax.eval_shape(
                lambda: model.init_caches(ecfg.policy, ecfg.batch, cap))
            self._cache_shard = shd.shardings_for(
                mesh, shd.cache_pspecs(self.cfg, cache_abs, mesh, ecfg.batch))
            pshard = shd.shardings_for(mesh, shd.param_pspecs(self.cfg, params, mesh))
            self.params = jax.device_put(params, pshard)
        else:
            self._cache_shard = None
            self.params = params

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, ecfg.policy, cap,
                                       prefill_mode=ecfg.prefill_mode,
                                       fused=ecfg.fused))
        # Mixed-length serving: prefill_slot buckets a raw-length prompt up
        # to the next n_b multiple (the padded tail lands in the FP16
        # streaming buffer, never in a compressed chunk), so jit compiles
        # one program per BUCKET instead of one per distinct prompt length.
        # Gated on the same predicate as the prefix cache — bucketing rides
        # the streaming pipeline's padded-tail path, so every layer must
        # support it; other engines prefill at the exact raw length (one
        # program per length).
        self.weight_version = 0
        self._can_bucket = (
            ecfg.prefill_mode is PrefillMode.STREAMING
            and prefix_cache_unsupported_reason(self.cfg, ecfg.policy, cap)
            is None)
        if self._can_bucket:
            self._prefill_bucketed = jax.jit(
                lambda p, b, tl: model.prefill(
                    p, b, ecfg.policy, cap, prefill_mode="streaming",
                    fused=ecfg.fused, padded_tail=True, true_len=tl))
        if self.layout is CacheLayout.PAGED:
            self._init_paged(cap)
            self._decode = jax.jit(
                lambda p, tok, caches, pos, bt: model.decode_step(
                    p, tok, caches, pos, ecfg.policy, cap, fused=ecfg.fused,
                    block_tables=bt),
                donate_argnums=(2,))
        else:
            self.pool = None
            self._decode = jax.jit(
                lambda p, tok, caches, pos: model.decode_step(
                    p, tok, caches, pos, ecfg.policy, cap, fused=ecfg.fused),
                donate_argnums=(2,))
        # Slot splice: write a batch-1 cache tree over batch row `slot` of the
        # live (donated) cache.  Cache leaves are stacked [R, B, ...], so the
        # batch dim is axis 1 on every leaf (incl. RWKV/SSM states); the
        # cache pspecs keep that axis's sharding uniform across leaves, which
        # is what keeps this DUS-at-a-traced-offset legal under SPMD.
        # Two variants: the per-request prefill splice also donates the
        # batch-1 tree (freshly built each request, consumed by the row
        # write) — but a [R, 1, ...] leaf can only alias into a [R, 1, ...]
        # output, so the extra donation applies on batch-1 engines only
        # (wider geometries would just trip XLA's unusable-donation
        # warning).  reset_slot must NOT donate its batch-1 tree — that is
        # the reusable `_fresh1` zero cache.
        splice = lambda full, one, slot: cache_lib.splice_slot(full, one, slot, axis=1)
        shard_kw = ({"out_shardings": self._cache_shard}
                    if self._cache_shard is not None else {})
        self._splice = jax.jit(splice, donate_argnums=(0,), **shard_kw)
        self._splice_donate_one = (
            jax.jit(splice, donate_argnums=(0, 1), **shard_kw)
            if ecfg.batch == 1 else self._splice)  # identical program otherwise
        self._fresh1 = None  # lazily-built batch-1 empty cache (for reset_slot)

        self.prefix_cache = None
        if ecfg.prefix_cache:
            reason = prefix_cache_unsupported_reason(self.cfg, ecfg.policy, cap)
            if reason is not None:
                raise ValueError(f"prefix_cache unsupported here: {reason}")
            store = (PagePoolStore(self.pool)
                     if self.layout is CacheLayout.PAGED else None)
            self.prefix_cache = PrefixCache(ecfg.policy.buffer_size,
                                            ecfg.prefix_cache_bytes, store=store,
                                            ttl=ecfg.prefix_cache_ttl,
                                            eviction=ecfg.prefix_cache_eviction,
                                            clock=self._clock,
                                            validate=ecfg.numeric_guard)
            self._cache_cfgs = [cache_cfg_for(self.cfg, kind, ecfg.policy, 1, cap)
                                for kind in self.cfg.layer_pattern]
            # per-shape jitted programs for the hit path, keyed by the
            # cached-prefix chunk count (suffix prefill; plus a padded-tail
            # flag for bucketed suffixes) and extraction chunk range —
            # length bucketing means only a handful of shapes ever occur;
            # jitting them matters because the eager versions pay one
            # dispatch per cache field per chunk.  The scaffold splice
            # needs no key: its trace depends only on the payload pytree
            # structure, which jit re-specializes on by itself.
            self._suffix_fns: dict[tuple[int, bool], Any] = {}
            self._extract_fns: dict[tuple[int, int], Any] = {}
            self._splice_prefix = jax.jit(
                lambda fresh, payloads: pc_store.splice_tree_chunks(
                    self._cache_cfgs, fresh, 0, payloads))

        # online compression-fidelity probes (repro.obs.fidelity): an fp16
        # shadow prefill of sampled prompts is the exact reference the
        # streaming pipeline discarded; the probe reads each sampled
        # request's batch-1 tree BEFORE the donating splice, so it can
        # never perturb serving state (probe-parity sweep in
        # tests/test_cache.py).  GEAR engines on text models only — other
        # modalities/policies have nothing to compare.
        if (self.obs is not None and ecfg.obs.fidelity_every_n > 0
                and not ecfg.policy.is_fp16 and self.cfg.modality == "text"):
            ref_jit = jax.jit(lambda p, b: model.prefill(p, b, FP16, cap))
            self.obs.fidelity = FidelityProbe(
                ref_prefill=lambda b: ref_jit(self.params, b),
                cache_cfgs=[None if kind == "rwkv"
                            else cache_cfg_for(self.cfg, kind, ecfg.policy,
                                               1, cap)
                            for kind in self.cfg.layer_pattern],
                policy=ecfg.policy, registry=self.obs.registry,
                every_n=ecfg.obs.fidelity_every_n,
                budget_frac=ecfg.obs.fidelity_budget_frac)

    # -- paged-layout setup --------------------------------------------
    def _init_paged(self, cap: int) -> None:
        ecfg = self.ecfg
        if ecfg.policy.is_fp16:
            raise ValueError(
                "paged layout requires a compressed (GEAR) policy: fp16 "
                "caches have no chunk pages to pool")
        if self.cfg.ssm and self.cfg.hybrid_parallel:
            raise NotImplementedError(
                "hybrid SSM recurrent state is not chunk-decomposable; "
                "serve it with layout='dense'")
        nb = ecfg.policy.buffer_size
        self._n_chunks = cap // nb
        # batch-1 per-position cache configs; which positions are pooled
        # mirrors transformer._unit_cache exactly (window/fp16/rwkv dense)
        self._pos_cfgs1 = [
            None if kind == "rwkv"
            else cache_cfg_for(self.cfg, kind, ecfg.policy, 1, cap)
            for kind in self.cfg.layer_pattern]
        self._paged_flags = [
            ccfg is not None and cache_lib.paged_supported(ccfg)
            for ccfg in self._pos_cfgs1]
        if not any(self._paged_flags):
            raise ValueError(
                "paged layout: no GEAR-compressible attention layer in "
                f"pattern {self.cfg.layer_pattern!r}")
        # one page = one chunk across the WHOLE model: R repeats of every
        # pooled position contribute their per-layer page cost
        R = self.cfg.pattern_repeats
        self._page_bytes = R * sum(
            cache_lib.page_nbytes(ccfg)
            for ccfg, flag in zip(self._pos_cfgs1, self._paged_flags) if flag)
        if ecfg.pool_pages:
            n_pages = ecfg.pool_pages
        elif ecfg.pool_bytes:
            n_pages = ecfg.pool_bytes // self._page_bytes + 1
        else:
            n_pages = ecfg.batch * self._n_chunks + 1   # dense-equivalent
        if n_pages < 2:
            raise ValueError(
                f"pool of {n_pages} pages cannot hold page 0 + one chunk "
                f"(page_bytes={self._page_bytes}; raise pool_bytes/pool_pages)")
        self._n_pages = n_pages
        self._paged_splice_fns: dict[int, Any] = {}
        self._new_pool()

    def _new_pool(self) -> None:
        """Fresh allocator + device block table (and, because trie payloads
        are page ids into the pool being discarded, a fresh prefix trie)."""
        self.pool = PagePool(self._n_pages, self.ecfg.batch, self._n_chunks,
                             self._page_bytes)
        self.pool.faults = self._faults
        self._bt = jnp.asarray(self.pool.block_tables)
        if getattr(self, "prefix_cache", None) is not None:
            self.prefix_cache = PrefixCache(self.ecfg.policy.buffer_size,
                                            self.ecfg.prefix_cache_bytes,
                                            store=PagePoolStore(self.pool),
                                            ttl=self.ecfg.prefix_cache_ttl,
                                            eviction=self.ecfg.prefix_cache_eviction,
                                            clock=self._clock)

    def _cap(self) -> int:
        nb = self.ecfg.policy.buffer_size
        return (self.ecfg.capacity + nb - 1) // nb * nb

    @property
    def attend_path(self) -> str:
        """Decode-attend path compiled into this engine's attention layers:
        "fused" (gear_attend — Pallas kernel on TPU, jnp oracle elsewhere),
        "fused-interpret" (kernel forced in interpret mode), or "xla"
        (no layer qualifies: fp16/window caches, unsupported layouts, or
        ``fused="off"``).  Checks every kind in the model's layer pattern —
        local/window layers never fuse, so a model needs at least one
        GEAR-layout attention layer to report a fused path.  The paged
        layout shares the dense kernel constraint (the paged kernel gathers
        pages by block-table index but runs the same compute body)."""
        fused_any = any(
            kernel_ops.fused_supported(cache_cfg_for(
                self.cfg, kind, self.ecfg.policy, self.ecfg.batch, self._cap()))
            for kind in self.cfg.layer_pattern if kind != "rwkv")
        if self.ecfg.fused is AttendPath.OFF or not fused_any:
            return "xla"
        return ("fused-interpret" if self.ecfg.fused is AttendPath.INTERPRET
                else "fused")

    # ------------------------------------------------------------------
    def attach_faults(self, injector) -> None:
        """Wire a :class:`~repro.serving.faults.FaultInjector` into the
        engine's chaos hooks (prefill corruption here, admission faults in
        the page pool).  ``None`` detaches.  Production never calls this —
        the scheduler does, when constructed with ``faults=...``."""
        self._faults = injector
        if self.pool is not None:
            self.pool.faults = injector
        if injector is not None:
            injector.obs = self.obs

    def _guard_one(self, one):
        """Numeric quarantine boundary for one request's batch-1 cache tree.

        Runs after the (cold or suffix) prefill and before anything shares
        the result — the batched splice, the trie insert, the page
        scatter.  The chaos injector's NaN corruption lands here too, so
        an injected poisoned chunk takes exactly the path a real one
        would.  Raises :class:`~repro.core.cache.NumericFault` with all
        shared state untouched; read-only otherwise (bit-identity safe).
        """
        if self._faults is not None:
            one = self._faults.corrupt_tree(one)
        if self.ecfg.numeric_guard and not bool(self._finite_fn(one)):
            if self.obs is not None:
                self.obs.quarantine()
                self.obs.tracer.event_bound("quarantine")
            raise cache_lib.NumericFault(
                "prefill produced NaN/Inf in a compressed chunk; "
                "quarantining this request (shared cache state untouched)")
        return one

    # -- observability hooks -------------------------------------------
    @property
    def _prof(self) -> bool:
        return self.obs is not None and self.obs.cfg.profiler

    def _span(self, name: str):
        """Trace span on the scheduler-bound rid; no-op without obs."""
        if self.obs is None:
            return contextlib.nullcontext()
        return self.obs.tracer.span_bound(name)

    def _obs_prefill(self, batch1, logits, one, n_hit: int = 0,
                     pages_reserved: int | None = None) -> None:
        """Per-prefill telemetry, called with the guarded batch-1 tree
        BEFORE the donating splice: annotates the scheduler's open prefill
        span (prefix hit / bucket / pages), feeds the bucket histogram,
        and hands the read-only tree to the fidelity probe."""
        o = self.obs
        if o is None:
            return
        plen = int(np.asarray(batch1["tokens"]).shape[-1])
        nb = self.ecfg.policy.buffer_size
        bucket = (plen + nb - 1) // nb * nb if self._can_bucket else plen
        o.observe_bucket(bucket)
        ann = {"prompt_tokens": plen, "bucket_tokens": bucket,
               "prefix_hit_chunks": n_hit}
        if pages_reserved is not None:
            ann["pages_reserved"] = pages_reserved
        o.tracer.annotate(**ann)
        if o.fidelity is not None:
            o.fidelity.maybe_probe(batch1, logits, one)

    def audit(self) -> dict:
        """Cross-structure invariant audit: page pool refcounts against
        block tables + live trie handles, plus the trie's own structural
        audit.  Returns ``{"ok", "issues", ...}``; never raises — the
        chaos suite asserts on it after every fault schedule."""
        issues: list[str] = []
        report: dict[str, Any] = {}
        if self.pool is not None:
            retained = None
            if self.prefix_cache is not None:
                retained = ([int(h) for h in self.prefix_cache.live_handles()]
                            + [int(h) for h in self.prefix_cache.trie.pending_free])
            report["pool"] = self.pool.audit(retained=retained)
            issues += [f"pool: {m}" for m in report["pool"]["issues"]]
        if self.prefix_cache is not None:
            report["trie"] = self.prefix_cache.audit()
            issues += [f"trie: {m}" for m in report["trie"]["issues"]]
        return {"ok": not issues, "issues": issues, **report}

    # ------------------------------------------------------------------
    def set_params(self, params: Any) -> None:
        """Swap the served weights (hot reload / fine-tune push).

        Bumps :attr:`weight_version` and invalidates every prefix-cache
        entry: cached chunks were compressed under the OLD weights, so
        splicing them into a new-weights prefill would silently serve
        stale activations.  The trie prunes lazily — the counters show up
        as ``version_evictions`` in :attr:`PrefixCache.stats`.
        """
        if self.mesh is not None:
            pshard = shd.shardings_for(
                self.mesh, shd.param_pspecs(self.cfg, params, self.mesh))
            params = jax.device_put(params, pshard)
        self.params = params
        self.weight_version += 1
        if self.prefix_cache is not None:
            self.prefix_cache.bump_version()

    # ------------------------------------------------------------------
    def prefill(self, batch: dict):
        if self.layout is CacheLayout.PAGED:
            raise NotImplementedError(
                "full-batch wave prefill is dense-only; paged engines serve "
                "through Engine.prefill_slot / Scheduler.run_continuous")
        logits, caches = self._prefill(self.params, batch)
        if self._cache_shard is not None:
            caches = jax.device_put(caches, self._cache_shard)
        return logits, caches

    def _cold_prefill(self, batch1: dict):
        """Batch-1 prompt prefill at bucketed length.

        A prompt whose raw length is not an ``n_b`` multiple is right-padded
        to the next bucket and run through the padded-tail streaming
        pipeline (pad tokens never reach compressed storage; cache lengths
        and logits reflect the raw length), so jit compiles one program per
        bucket.  Aligned prompts — and engines that cannot bucket — take
        the plain prefill program at the exact length.
        """
        n = batch1["tokens"].shape[1]
        nb = self.ecfg.policy.buffer_size
        with profiler_span("gear.prefill", self._prof):
            if not self._can_bucket or n % nb == 0:
                return self._prefill(self.params, batch1)
            n_bucket = (n + nb - 1) // nb * nb
            toks = jnp.asarray(batch1["tokens"], jnp.int32)
            padded = {"tokens": jnp.pad(toks, ((0, 0), (0, n_bucket - n)))}
            return self._prefill_bucketed(self.params, padded, jnp.int32(n))

    def decode(self, token_batch: dict, caches, pos):
        """One decode step.  ``pos``: scalar or per-slot [B] int32 vector."""
        with profiler_span("gear.decode", self._prof):
            if self.layout is CacheLayout.PAGED:
                return self._decode(self.params, token_batch, caches,
                                    jnp.asarray(pos, jnp.int32), self._bt)
            return self._decode(self.params, token_batch, caches,
                                jnp.asarray(pos, jnp.int32))

    # -- slot-level continuous batching --------------------------------
    def prefill_slot(self, batch1: dict, caches, slot: int, admit: bool = True,
                     reserve_tokens: int | None = None):
        """Prefill ONE request (batch-1 inputs) and splice it into ``slot``.

        Returns (logits [1, 1, ...] for the request's last prompt position,
        new caches).  The batch-1 prefill is bit-identical to a solo run of
        the same prompt, so a spliced request decodes exactly as it would
        alone (DESIGN.md §splice isolation).  Both the live ``caches`` tree
        and the request's batch-1 tree are donated into the splice, so the
        per-request path is one batch-row write with no tree copies.  With
        ``prefill_mode="streaming"`` the batch-1 prefill never materializes
        the prompt's FP16 K/V, so long-prompt splices stay within the
        compressed-cache memory budget.

        ``batch1`` carries the RAW prompt (no scheduler padding).  Prompts
        whose length is not an ``n_b`` multiple are length-bucketed: padded
        up to the next chunk multiple and run through the padded-tail
        streaming pipeline, so jit compiles one program per bucket while
        cache lengths, logits, and trie keys all reflect the true length
        (engines that cannot take the streaming pipeline prefill at the
        exact raw length instead — one compile per distinct length).

        With ``EngineConfig.prefix_cache`` on, the trie is consulted first:
        the longest cached chunk-aligned prefix of the raw prompt is
        spliced straight into a batch-1 cache tree and only the remaining
        suffix runs streaming prefill (bucketed the same way), with the
        prefix visible as already-compressed history — bit-identical caches
        and logits vs the cold bucketed path (DESIGN.md §4).  ``admit`` is
        the scheduler's admission policy: when True the prompt's newly
        closed chunks are inserted back into the trie after prefill — only
        FULL ``n_b``-token chunks of real tokens close, so pad garbage
        never enters the trie.

        PAGED layout: the slot first reserves its lifetime's pages from the
        pool — ``reserve_tokens`` (prompt + generation budget; defaults to
        full capacity) right-sizes the reservation, which is where paged
        concurrency comes from.  Prefix-cache hits arrive as shared page
        ids (refcount bump, no copy); fresh pages are zeroed before the
        block-table row exposes them and the prompt's closed chunks are
        scattered in.  Raises :class:`~repro.serving.pagedpool.PoolExhausted`
        — with no device work done — when the pool cannot cover the
        reservation; the scheduler queues and retries.
        """
        if self.layout is CacheLayout.PAGED:
            return self._prefill_slot_paged(batch1, caches, slot, admit,
                                            reserve_tokens)
        if self.prefix_cache is None:
            logits, one = self._cold_prefill(batch1)
            one = self._guard_one(one)
            self._obs_prefill(batch1, logits, one)
            with self._span("splice"):
                return logits, self._splice_donate_one(
                    caches, one, jnp.asarray(slot, jnp.int32))
        tokens = np.asarray(batch1["tokens"][0])
        nb = self.ecfg.policy.buffer_size
        n = tokens.shape[0]
        # always leave >= 1 suffix token so prefill computes the
        # last-position logits the first sampled token comes from
        match = self.prefix_cache.match(tokens, max_chunks=max((n - 1) // nb, 0))
        n_hit = match.n_chunks
        try:
            if n_hit:
                one1 = self._splice_prefix(self._fresh_batch1(),
                                           match.payloads)
                logits, one = self._prefill_suffix(tokens, n_hit, one1)
            else:
                logits, one = self._cold_prefill(batch1)
            one = self._guard_one(one)
            self._obs_prefill(batch1, logits, one, n_hit=n_hit)
            if admit and n // nb > n_hit:
                payloads = self._extract_fn(n_hit, n // nb)(one)
                self.prefix_cache.insert(tokens, payloads, start_chunk=n_hit)
        finally:
            self.prefix_cache.release(match)
        with self._span("splice"):
            return logits, self._splice_donate_one(
                caches, one, jnp.asarray(slot, jnp.int32))

    def _prefill_suffix(self, tokens: np.ndarray, n_hit: int, one1):
        """Run the (possibly bucketed) suffix after an ``n_hit``-chunk trie
        hit over the spliced batch-1 scaffold ``one1``."""
        nb = self.ecfg.policy.buffer_size
        suf = np.asarray(tokens[n_hit * nb:], np.int32)
        n_suf = suf.shape[0]
        with profiler_span("gear.prefill_suffix", self._prof):
            if n_suf % nb == 0:
                suffix = {"tokens": jnp.asarray(suf[None], jnp.int32)}
                return self._suffix_fn(n_hit)(self.params, suffix, one1)
            n_bucket = (n_suf + nb - 1) // nb * nb
            padded = {"tokens": jnp.pad(jnp.asarray(suf[None], jnp.int32),
                                        ((0, 0), (0, n_bucket - n_suf)))}
            return self._suffix_fn(n_hit, padded_tail=True)(
                self.params, padded, one1, jnp.int32(n_suf))

    def _prefill_slot_paged(self, batch1, caches, slot, admit, reserve_tokens):
        nb = self.ecfg.policy.buffer_size
        cap = self._cap()
        plen = self._prompt_len(batch1)
        n_closed = plen // nb                 # chunks the prompt closes
        reserve = cap if reserve_tokens is None else min(int(reserve_tokens), cap)
        n_total = max(pages_needed(max(reserve, plen), nb), n_closed)

        match, n_hit, shared = None, 0, []
        if self.prefix_cache is not None:
            tokens = np.asarray(batch1["tokens"][0])
            match = self.prefix_cache.match(
                tokens, max_chunks=max((plen - 1) // nb, 0))
            n_hit = match.n_chunks
            shared = [int(p) for p in match.payloads]   # payloads ARE page ids
        try:
            # splicing over a live slot discards its previous request (the
            # dense layout overwrites the row; here we release its pages)
            if self.pool.slot_pages(slot).size:
                self.pool.release_slot(slot)
            # host-side reservation FIRST — PoolExhausted costs no device work
            fresh = self.pool.admit(slot, n_total, shared=shared)
            try:
                if n_hit:
                    one1 = self._gather_scaffold(
                        caches, self._fresh_batch1(),
                        jnp.asarray(shared, jnp.int32))
                    logits, one = self._prefill_suffix(tokens, n_hit, one1)
                else:
                    logits, one = self._cold_prefill(batch1)
                # quarantine BEFORE the donating splice: on failure the live
                # tree is untouched and the reservation rolls back below
                one = self._guard_one(one)
            except BaseException:
                self.pool.release_slot(slot)
                self._bt = jnp.asarray(self.pool.block_tables)
                raise
            self._obs_prefill(batch1, logits, one, n_hit=n_hit,
                              pages_reserved=n_total)
            n_sc = n_closed - n_hit
            with self._span("splice"):
                caches = self._paged_splice_fn(n_hit)(
                    caches, one,
                    jnp.asarray(fresh[n_sc:], jnp.int32),   # reserved: zero
                    jnp.asarray(fresh[:n_sc], jnp.int32),   # closed: scatter
                    jnp.asarray(slot, jnp.int32))
            self._bt = jnp.asarray(self.pool.block_tables)
            if self.prefix_cache is not None and admit and n_closed > n_hit:
                row = self.pool.block_tables[slot]
                self.prefix_cache.insert(
                    tokens, [int(p) for p in row[n_hit:n_closed]],
                    start_chunk=n_hit)
        finally:
            if match is not None:
                self.prefix_cache.release(match)
        return logits, caches

    def _paged_splice_fn(self, c_lo: int):
        """Jitted paged slot splice: zero the slot's reserved pages, scatter
        the batch-1 prefill's closed chunks ``[c_lo, c_lo + n_sc)`` into its
        fresh pages, and row-write the streaming buffer / length (dense
        positions in a mixed tree splice whole, as before).  Keyed by the
        prefix chunk offset; jit re-specializes on the page-count shapes."""
        fn = self._paged_splice_fns.get(c_lo)
        if fn is None:
            def impl(caches, one, zero_pages, sc_pages, slot):
                n_sc = sc_pages.shape[0]
                out = []
                for i, flag in enumerate(self._paged_flags):
                    if not flag:
                        out.append(cache_lib.splice_slot(
                            caches[i], one[i], slot, axis=1))
                        continue
                    ccfg1 = self._pos_cfgs1[i]

                    def upd(lyr, one_lyr, ccfg1=ccfg1):
                        lyr = cache_lib.zero_pool_pages(ccfg1, lyr, zero_pages)
                        if n_sc:
                            chunks = cache_lib.extract_prefix_chunks(
                                ccfg1, one_lyr, n_sc, c_lo)
                            lyr = cache_lib.scatter_pool_chunks(
                                ccfg1, lyr, sc_pages, chunks)
                        return lyr

                    lyr = jax.vmap(upd)(caches[i], one[i])   # over repeats R
                    sub = cache_lib.splice_slot(
                        {"buf_k": lyr.buf_k, "buf_v": lyr.buf_v,
                         "length": lyr.length},
                        {"buf_k": one[i].buf_k, "buf_v": one[i].buf_v,
                         "length": one[i].length},
                        slot, axis=1)
                    out.append(dataclasses.replace(lyr, **sub))
                return tuple(out)

            fn = jax.jit(impl, donate_argnums=(0,))
            self._paged_splice_fns[c_lo] = fn
        return fn

    def _gather_scaffold_impl(self, caches, fresh, pages):
        """Trace: gather prefix pages out of the pool into the batch-1 dense
        scaffold the suffix prefill runs over — the paged twin of the dense
        engine's host-payload ``_splice_prefix``."""
        n_hit = pages.shape[0]
        per_pos = []
        for i, flag in enumerate(self._paged_flags):
            ccfg1 = self._pos_cfgs1[i]
            per_pos.append(jax.vmap(
                lambda lyr, ccfg1=ccfg1: cache_lib.gather_pool_chunks(
                    ccfg1, lyr, pages))(caches[i]))
        payloads = [tuple(p[c] for p in per_pos) for c in range(n_hit)]
        return pc_store.splice_tree_chunks(self._cache_cfgs, fresh, 0, payloads)

    def _gather_scaffold(self, caches, fresh, pages):
        # prefix_cache requires every layer paged-capable, so per_pos covers
        # all positions; jit re-specializes per distinct page count
        if not hasattr(self, "_gather_fn"):
            self._gather_fn = jax.jit(self._gather_scaffold_impl)
        return self._gather_fn(caches, fresh, pages)

    def _fresh_batch1(self):
        """Memoized empty batch-1 cache tree (read-only — splices copy out
        of it; never donate it into a jitted program)."""
        if self._fresh1 is None:
            self._fresh1 = self.model.init_caches(self.ecfg.policy, 1, self._cap())
        return self._fresh1

    def _suffix_fn(self, n_pre_chunks: int, padded_tail: bool = False):
        """Jitted suffix prefill for a ``n_pre_chunks``-chunk cached prefix.

        The prefix length is static (it fixes every array shape in the
        suffix pipeline), so programs are compiled per distinct chunk
        count; ``padded_tail=True`` is the bucketed-suffix variant, which
        additionally takes the traced true suffix length (jit then
        re-specializes per bucket width on top).  The scaffold tree is NOT
        donated: the streaming store path assembles each cache array from
        the stacked compression-scan outputs, so XLA cannot alias any
        input leaf into its output (every leaf would trip the
        unusable-donation warning) — and the un-donated scaffold may alias
        the memoized ``_fresh_batch1`` tree's buffer/length leaves safely.
        """
        fn = self._suffix_fns.get((n_pre_chunks, padded_tail))
        if fn is None:
            start = n_pre_chunks * self.ecfg.policy.buffer_size
            if padded_tail:
                fn = jax.jit(
                    lambda p, b, c1, tl: self.model.prefill_suffix(
                        p, b, c1, start, self.ecfg.policy, self._cap(),
                        fused=self.ecfg.fused, padded_tail=True,
                        true_len=tl))
            else:
                fn = jax.jit(
                    lambda p, b, c1: self.model.prefill_suffix(
                        p, b, c1, start, self.ecfg.policy, self._cap(),
                        fused=self.ecfg.fused))
            self._suffix_fns[(n_pre_chunks, padded_tail)] = fn
        return fn

    def _extract_fn(self, c_lo: int, c_hi: int):
        """Jitted chunk extraction from a batch-1 cache tree."""
        fn = self._extract_fns.get((c_lo, c_hi))
        if fn is None:
            fn = jax.jit(lambda caches: pc_store.extract_tree_chunks(
                self._cache_cfgs, caches, c_lo, c_hi))
            self._extract_fns[(c_lo, c_hi)] = fn
        return fn

    def reset_slot(self, caches, slot: int):
        """Return ``caches`` with batch row ``slot`` cleared to empty state.

        PAGED: releases the slot's block-table row back to the pool (pure
        host refcounting — freed pages are re-zeroed at their NEXT
        admission, so release does no device work beyond the buffer/length
        row clear)."""
        if self.layout is CacheLayout.PAGED:
            self.pool.release_slot(slot)
            self._bt = jnp.asarray(self.pool.block_tables)
            if not hasattr(self, "_paged_reset_fn"):
                def impl(caches, fresh1, slot):
                    out = []
                    for i, flag in enumerate(self._paged_flags):
                        if not flag:
                            out.append(cache_lib.splice_slot(
                                caches[i], fresh1[i], slot, axis=1))
                            continue
                        sub = cache_lib.splice_slot(
                            {"buf_k": caches[i].buf_k, "buf_v": caches[i].buf_v,
                             "length": caches[i].length},
                            {"buf_k": fresh1[i].buf_k, "buf_v": fresh1[i].buf_v,
                             "length": fresh1[i].length},
                            slot, axis=1)
                        out.append(dataclasses.replace(caches[i], **sub))
                    return tuple(out)
                self._paged_reset_fn = jax.jit(impl, donate_argnums=(0,))
            return self._paged_reset_fn(caches, self._fresh_batch1(),
                                        jnp.asarray(slot, jnp.int32))
        return self._splice(caches, self._fresh_batch1(),
                            jnp.asarray(slot, jnp.int32))

    def reclaim_pages(self, n_pages: int) -> int:
        """Evict prefix-trie entries until ``n_pages`` pool pages came free
        (or nothing evictable remains).  The scheduler's deadlock valve:
        with every slot idle, the only references keeping pages off the
        free list are the trie's.  Returns pages actually reclaimed."""
        if self.pool is None or self.prefix_cache is None:
            return 0
        freed = self.prefix_cache.evict_bytes(n_pages * self.pool.page_bytes)
        return freed // self.pool.page_bytes

    # ------------------------------------------------------------------
    def generate(self, batch: dict, max_new_tokens: int, key=None, active=None):
        """Greedy/sampled wave generation.  Returns (tokens [B, T], stats).

        ``active``: optional bool mask [B] of slots holding real requests;
        padded copy slots are excluded from the throughput accounting.
        Dense-layout only — paged engines serve through continuous batching
        (:meth:`repro.serving.scheduler.Scheduler.run_continuous`).
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        cfg, ecfg = self.cfg, self.ecfg
        t0 = time.time()
        logits, caches = self.prefill(batch)
        t_prefill = time.time() - t0
        prompt_len = self._prompt_len(batch)
        B = logits.shape[0]

        tok = sample(logits[:, -1], key, ecfg.temperature, ecfg.top_k)
        out = [tok]
        done = jnp.zeros(tok.shape[:1], bool)
        t1 = time.time()
        for t in range(max_new_tokens - 1):
            tb = {"tokens": tok[:, None] if cfg.modality != "audio" else tok[:, None, :]}
            # per-slot position vector: the same decode program serves the
            # continuous-batching path, where positions genuinely differ.
            pos = jnp.full((B,), prompt_len + t, jnp.int32)
            logits, caches = self.decode(tb, caches, pos)
            key = jax.random.fold_in(key, t)
            tok = sample(logits[:, -1], key, ecfg.temperature, ecfg.top_k)
            if ecfg.eos_id >= 0:
                done = done | (tok == ecfg.eos_id) if cfg.modality != "audio" else done
                tok = jnp.where(done, ecfg.eos_id, tok) if cfg.modality != "audio" else tok
            out.append(tok)
            if ecfg.eos_id >= 0 and bool(done.all()):
                break
        toks = jnp.stack(out, axis=1)
        t_decode = time.time() - t1
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": self._decode_tok_per_s(toks, t_decode, active),
            "cache_bytes": self.cache_nbytes(caches),
        }
        return toks, stats

    def _decode_tok_per_s(self, toks, t_decode: float, active) -> float:
        """Decode throughput over USEFUL tokens only: padded copy slots
        (``active`` False) and post-EOS / early-exit filler are excluded, so
        bench numbers aren't inflated by throwaway work."""
        tnp = np.asarray(toks)
        B, T = tnp.shape[0], tnp.shape[1]
        act = np.ones(B, bool) if active is None else np.asarray(active, bool)
        n_use = np.full(B, T)
        if self.ecfg.eos_id >= 0 and self.cfg.modality != "audio":
            hit = tnp == self.ecfg.eos_id
            has = hit.any(axis=1)
            n_use[has] = hit.argmax(axis=1)[has] + 1  # keep the EOS itself
        useful_decode = int(np.maximum(n_use - 1, 0)[act].sum())  # 1st tok = prefill
        return useful_decode / max(t_decode, 1e-9)

    def _prompt_len(self, batch) -> int:
        n = batch["tokens"].shape[1]
        if self.cfg.modality == "vlm":
            n += self.cfg.num_prefix_tokens
        return n

    def init_caches(self):
        if self.layout is CacheLayout.PAGED:
            # a fresh tree zeroes the pool device-side, so the allocator
            # (and the trie, whose payloads are ids into the old pool)
            # must restart with it
            self._new_pool()
            return self.model.init_caches(self.ecfg.policy, self.ecfg.batch,
                                          self._cap(), layout="paged",
                                          pool_pages=self._n_pages)
        caches = self.model.init_caches(self.ecfg.policy, self.ecfg.batch, self._cap())
        if self._cache_shard is not None:
            caches = jax.device_put(caches, self._cache_shard)
        return caches

    def new_view(self):
        """Blessed slot-API facade over a fresh cache tree
        (:class:`repro.serving.views.CacheView`): the scheduler drives the
        view instead of threading raw trees through free functions."""
        from repro.serving.views import DenseCacheView, PagedCacheView
        caches = self.init_caches()
        if self.layout is CacheLayout.PAGED:
            return PagedCacheView(self, caches)
        return DenseCacheView(self, caches)

    @staticmethod
    def cache_nbytes(caches) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
