"""Serving engine: prefill + GEAR-cached decode, sharded over the mesh.

The engine owns the jitted prefill/decode programs (cache donated across
steps so decode is allocation-free), token sampling, and the byte-level
cache accounting the memory benchmarks read.  Request-level batching is in
:mod:`repro.serving.scheduler`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import CompressionPolicy
from repro.dist import sharding as shd
from repro.models.model import Model
from repro.serving.sampling import sample

__all__ = ["EngineConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch: int
    capacity: int                  # max total tokens per sequence
    policy: CompressionPolicy
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1               # -1: never stop early


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig, mesh=None):
        self.model = model
        self.cfg = model.cfg
        self.ecfg = ecfg
        self.mesh = mesh
        cap = self._cap()

        if mesh is not None:
            cache_abs = jax.eval_shape(
                lambda: model.init_caches(ecfg.policy, ecfg.batch, cap))
            self._cache_shard = shd.shardings_for(
                mesh, shd.cache_pspecs(self.cfg, cache_abs, mesh, ecfg.batch))
            pshard = shd.shardings_for(mesh, shd.param_pspecs(self.cfg, params, mesh))
            self.params = jax.device_put(params, pshard)
        else:
            self._cache_shard = None
            self.params = params

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, ecfg.policy, cap))
        self._decode = jax.jit(
            lambda p, tok, caches, pos: model.decode_step(
                p, tok, caches, pos, ecfg.policy, cap),
            donate_argnums=(2,))

    def _cap(self) -> int:
        nb = self.ecfg.policy.buffer_size
        return (self.ecfg.capacity + nb - 1) // nb * nb

    # ------------------------------------------------------------------
    def prefill(self, batch: dict):
        logits, caches = self._prefill(self.params, batch)
        if self._cache_shard is not None:
            caches = jax.device_put(caches, self._cache_shard)
        return logits, caches

    def decode(self, token_batch: dict, caches, pos: int):
        return self._decode(self.params, token_batch, caches, jnp.asarray(pos, jnp.int32))

    def generate(self, batch: dict, max_new_tokens: int, key=None):
        """Greedy/sampled generation.  Returns (tokens [B, T], stats)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        cfg, ecfg = self.cfg, self.ecfg
        t0 = time.time()
        logits, caches = self.prefill(batch)
        t_prefill = time.time() - t0
        prompt_len = self._prompt_len(batch)

        tok = sample(logits[:, -1], key, ecfg.temperature, ecfg.top_k)
        out = [tok]
        done = jnp.zeros(tok.shape[:1], bool)
        t1 = time.time()
        for t in range(max_new_tokens - 1):
            tb = {"tokens": tok[:, None] if cfg.modality != "audio" else tok[:, None, :]}
            logits, caches = self.decode(tb, caches, prompt_len + t)
            key = jax.random.fold_in(key, t)
            tok = sample(logits[:, -1], key, ecfg.temperature, ecfg.top_k)
            if ecfg.eos_id >= 0:
                done = done | (tok == ecfg.eos_id) if cfg.modality != "audio" else done
                tok = jnp.where(done, ecfg.eos_id, tok) if cfg.modality != "audio" else tok
            out.append(tok)
            if ecfg.eos_id >= 0 and bool(done.all()):
                break
        toks = jnp.stack(out, axis=1)
        t_decode = time.time() - t1
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": toks.shape[0] * (toks.shape[1] - 1) / max(t_decode, 1e-9),
            "cache_bytes": self.cache_nbytes(caches),
        }
        return toks, stats

    def _prompt_len(self, batch) -> int:
        n = batch["tokens"].shape[1]
        if self.cfg.modality == "vlm":
            n += self.cfg.num_prefix_tokens
        return n

    def init_caches(self):
        caches = self.model.init_caches(self.ecfg.policy, self.ecfg.batch, self._cap())
        if self._cache_shard is not None:
            caches = jax.device_put(caches, self._cache_shard)
        return caches

    @staticmethod
    def cache_nbytes(caches) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
