"""Serving engine: prefill + GEAR-cached decode, sharded over the mesh.

The engine owns the jitted prefill/decode programs (cache donated across
steps so decode is allocation-free), token sampling, and the byte-level
cache accounting the memory benchmarks read.  Two batching modes sit on
top (:mod:`repro.serving.scheduler`):

* wave mode — :meth:`Engine.generate` drives the whole batch in lockstep;
* continuous mode — the scheduler drives :meth:`Engine.decode` one step at
  a time with per-slot position vectors, and :meth:`Engine.prefill_slot`
  splices a fresh request's batch-1 cache into a live batch slot (the cache
  tree is donated, so the splice is an in-place batch-row write).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core.policy import CompressionPolicy
from repro.dist import sharding as shd
from repro.kernels import ops as kernel_ops
from repro.models import attention as attn_lib
from repro.models.model import Model
from repro.models.transformer import cache_cfg_for
from repro.prefixcache import PrefixCache
from repro.prefixcache import store as pc_store
from repro.serving.sampling import sample

__all__ = ["EngineConfig", "Engine", "prefix_cache_unsupported_reason"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch: int
    capacity: int                  # max total tokens per sequence
    policy: CompressionPolicy
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1               # -1: never stop early
    # GEAR decode-attend path: "auto" (fused gear_attend where the cache
    # layout supports it — kernel on TPU, oracle elsewhere; ragged-aware so
    # continuous batching takes it too), "interpret" (force the Pallas
    # kernel in interpret mode — CI kernel lane), "off" (jnp cache.attend).
    # The same knob selects the prefill kernel path (flash_prefill for
    # monolithic attention, gear_compress/gear_attend_block for streaming).
    fused: str = "auto"
    # Prefill pipeline: "monolithic" (full-sequence attention then one
    # batched compression event) or "streaming" (compress-as-you-go chunked
    # pipeline — peak prefill memory is the compressed cache plus one chunk
    # instead of the full FP16 history; both build bit-identical caches).
    prefill_mode: str = "monolithic"
    # Cross-request prefix cache (radix trie over compressed GEAR chunks,
    # repro.prefixcache): prefill_slot splices the longest cached
    # chunk-aligned prompt prefix into the slot and streams only the
    # suffix — bit-identical caches/logits vs a cold prefill.  Requires
    # prefill_mode="streaming" (the hit path attends the cached prefix in
    # compressed form, which is exactly streaming's numeric model) and a
    # model whose every layer supports the streaming pipeline.
    prefix_cache: bool = False
    prefix_cache_bytes: int = 256 << 20   # trie LRU byte budget

    def __post_init__(self):
        if self.fused not in ("auto", "interpret", "off"):
            raise ValueError(f"fused must be auto/interpret/off, got {self.fused!r}")
        if self.prefill_mode not in ("monolithic", "streaming"):
            raise ValueError(
                f"prefill_mode must be monolithic/streaming, got {self.prefill_mode!r}")
        if self.prefix_cache and self.prefill_mode != "streaming":
            raise ValueError(
                "prefix_cache requires prefill_mode='streaming': the hit "
                "path attends the cached prefix in compressed form, so only "
                "streaming cold prefills are bit-identical to warm ones")


def prefix_cache_unsupported_reason(cfg, policy: CompressionPolicy,
                                    capacity: int) -> str | None:
    """Why this model/policy cannot take the prefix cache (None = it can).

    The hit path replays a cached chunk-aligned prefix as compressed
    history under the streaming suffix pipeline, so every layer must (a)
    keep all its prefill state in spliceable GEAR chunks and (b) support
    streaming prefill.  RWKV / hybrid-SSM recurrent states and the VLM
    bidirectional image prefix are neither; fp16 policies have no
    compressed chunks to cache.
    """
    if policy.is_fp16:
        return "fp16 policy has no compressed chunks to cache"
    if cfg.modality != "text":
        return f"modality {cfg.modality!r} (prompt is not a flat token-id sequence)"
    if cfg.ssm and cfg.hybrid_parallel:
        return "hybrid SSM state is not chunk-decomposable"
    for kind in cfg.layer_pattern:
        if kind == "rwkv":
            return "rwkv layers carry recurrent state, not spliceable chunks"
        ccfg = cache_cfg_for(cfg, kind, policy, 1, capacity)
        if not attn_lib.streaming_prefill_supported(cfg, kind, ccfg):
            return (f"layer kind {kind!r} does not support the streaming "
                    "prefill pipeline")
    return None


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig, mesh=None):
        self.model = model
        self.cfg = model.cfg
        self.ecfg = ecfg
        self.mesh = mesh
        cap = self._cap()

        if mesh is not None:
            cache_abs = jax.eval_shape(
                lambda: model.init_caches(ecfg.policy, ecfg.batch, cap))
            self._cache_shard = shd.shardings_for(
                mesh, shd.cache_pspecs(self.cfg, cache_abs, mesh, ecfg.batch))
            pshard = shd.shardings_for(mesh, shd.param_pspecs(self.cfg, params, mesh))
            self.params = jax.device_put(params, pshard)
        else:
            self._cache_shard = None
            self.params = params

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, ecfg.policy, cap,
                                       prefill_mode=ecfg.prefill_mode,
                                       fused=ecfg.fused))
        self._decode = jax.jit(
            lambda p, tok, caches, pos: model.decode_step(
                p, tok, caches, pos, ecfg.policy, cap, fused=ecfg.fused),
            donate_argnums=(2,))
        # Slot splice: write a batch-1 cache tree over batch row `slot` of the
        # live (donated) cache.  Cache leaves are stacked [R, B, ...], so the
        # batch dim is axis 1 on every leaf (incl. RWKV/SSM states); the
        # cache pspecs keep that axis's sharding uniform across leaves, which
        # is what keeps this DUS-at-a-traced-offset legal under SPMD.
        # Two variants: the per-request prefill splice also donates the
        # batch-1 tree (freshly built each request, consumed by the row
        # write) — but a [R, 1, ...] leaf can only alias into a [R, 1, ...]
        # output, so the extra donation applies on batch-1 engines only
        # (wider geometries would just trip XLA's unusable-donation
        # warning).  reset_slot must NOT donate its batch-1 tree — that is
        # the reusable `_fresh1` zero cache.
        splice = lambda full, one, slot: cache_lib.splice_slot(full, one, slot, axis=1)
        shard_kw = ({"out_shardings": self._cache_shard}
                    if self._cache_shard is not None else {})
        self._splice = jax.jit(splice, donate_argnums=(0,), **shard_kw)
        self._splice_donate_one = (
            jax.jit(splice, donate_argnums=(0, 1), **shard_kw)
            if ecfg.batch == 1 else self._splice)  # identical program otherwise
        self._fresh1 = None  # lazily-built batch-1 empty cache (for reset_slot)

        self.prefix_cache = None
        if ecfg.prefix_cache:
            reason = prefix_cache_unsupported_reason(self.cfg, ecfg.policy, cap)
            if reason is not None:
                raise ValueError(f"prefix_cache unsupported here: {reason}")
            self.prefix_cache = PrefixCache(ecfg.policy.buffer_size,
                                            ecfg.prefix_cache_bytes)
            self._cache_cfgs = [cache_cfg_for(self.cfg, kind, ecfg.policy, 1, cap)
                                for kind in self.cfg.layer_pattern]
            # per-shape jitted programs for the hit path, keyed by the
            # cached-prefix chunk count (suffix prefill) and extraction
            # chunk range — padded prompts mean only a handful of shapes
            # ever occur; jitting them matters because the eager versions
            # pay one dispatch per cache field per chunk.  The scaffold
            # splice needs no key: its trace depends only on the payload
            # pytree structure, which jit re-specializes on by itself.
            self._suffix_fns: dict[int, Any] = {}
            self._extract_fns: dict[tuple[int, int], Any] = {}
            self._splice_prefix = jax.jit(
                lambda fresh, payloads: pc_store.splice_tree_chunks(
                    self._cache_cfgs, fresh, 0, payloads))

    def _cap(self) -> int:
        nb = self.ecfg.policy.buffer_size
        return (self.ecfg.capacity + nb - 1) // nb * nb

    @property
    def attend_path(self) -> str:
        """Decode-attend path compiled into this engine's attention layers:
        "fused" (gear_attend — Pallas kernel on TPU, jnp oracle elsewhere),
        "fused-interpret" (kernel forced in interpret mode), or "xla"
        (no layer qualifies: fp16/window caches, unsupported layouts, or
        ``fused="off"``).  Checks every kind in the model's layer pattern —
        local/window layers never fuse, so a model needs at least one
        GEAR-layout attention layer to report a fused path."""
        fused_any = any(
            kernel_ops.fused_supported(cache_cfg_for(
                self.cfg, kind, self.ecfg.policy, self.ecfg.batch, self._cap()))
            for kind in self.cfg.layer_pattern if kind != "rwkv")
        if self.ecfg.fused == "off" or not fused_any:
            return "xla"
        return "fused-interpret" if self.ecfg.fused == "interpret" else "fused"

    # ------------------------------------------------------------------
    def prefill(self, batch: dict):
        logits, caches = self._prefill(self.params, batch)
        if self._cache_shard is not None:
            caches = jax.device_put(caches, self._cache_shard)
        return logits, caches

    def decode(self, token_batch: dict, caches, pos):
        """One decode step.  ``pos``: scalar or per-slot [B] int32 vector."""
        return self._decode(self.params, token_batch, caches,
                            jnp.asarray(pos, jnp.int32))

    # -- slot-level continuous batching --------------------------------
    def prefill_slot(self, batch1: dict, caches, slot: int, admit: bool = True):
        """Prefill ONE request (batch-1 inputs) and splice it into ``slot``.

        Returns (logits [1, 1, ...] for the request's last prompt position,
        new caches).  The batch-1 prefill is bit-identical to a solo run of
        the same prompt, so a spliced request decodes exactly as it would
        alone (DESIGN.md §splice isolation).  Both the live ``caches`` tree
        and the request's batch-1 tree are donated into the splice, so the
        per-request path is one batch-row write with no tree copies.  With
        ``prefill_mode="streaming"`` the batch-1 prefill never materializes
        the prompt's FP16 K/V, so long-prompt splices stay within the
        compressed-cache memory budget.

        With ``EngineConfig.prefix_cache`` on, the trie is consulted first:
        the longest cached chunk-aligned prefix of the (padded) prompt is
        spliced straight into a batch-1 cache tree and only the remaining
        suffix runs streaming prefill, with the prefix visible as
        already-compressed history — bit-identical caches and logits vs the
        cold path (DESIGN.md §4).  ``admit`` is the scheduler's admission
        policy: when True the prompt's newly closed chunks are inserted
        back into the trie after prefill.
        """
        if self.prefix_cache is None:
            logits, one = self._prefill(self.params, batch1)
            return logits, self._splice_donate_one(caches, one,
                                                   jnp.asarray(slot, jnp.int32))
        tokens = np.asarray(batch1["tokens"][0])
        nb = self.ecfg.policy.buffer_size
        n = tokens.shape[0]
        # always leave >= 1 suffix token so prefill computes the
        # last-position logits the first sampled token comes from
        match = self.prefix_cache.match(tokens, max_chunks=max((n - 1) // nb, 0))
        n_hit = match.n_chunks
        try:
            if n_hit:
                one1 = self._splice_prefix(self._fresh_batch1(),
                                           match.payloads)
                suffix = {"tokens": jnp.asarray(tokens[None, n_hit * nb:],
                                                jnp.int32)}
                logits, one = self._suffix_fn(n_hit)(self.params, suffix, one1)
            else:
                logits, one = self._prefill(self.params, batch1)
            if admit and n // nb > n_hit:
                payloads = self._extract_fn(n_hit, n // nb)(one)
                self.prefix_cache.insert(tokens, payloads, start_chunk=n_hit)
        finally:
            self.prefix_cache.release(match)
        return logits, self._splice_donate_one(caches, one,
                                               jnp.asarray(slot, jnp.int32))

    def _fresh_batch1(self):
        """Memoized empty batch-1 cache tree (read-only — splices copy out
        of it; never donate it into a jitted program)."""
        if self._fresh1 is None:
            self._fresh1 = self.model.init_caches(self.ecfg.policy, 1, self._cap())
        return self._fresh1

    def _suffix_fn(self, n_pre_chunks: int):
        """Jitted suffix prefill for a ``n_pre_chunks``-chunk cached prefix.

        The prefix length is static (it fixes every array shape in the
        suffix pipeline), so programs are compiled per distinct chunk
        count.  The scaffold tree is NOT donated: the streaming store path
        assembles each cache array from the stacked compression-scan
        outputs, so XLA cannot alias any input leaf into its output (every
        leaf would trip the unusable-donation warning) — and the
        un-donated scaffold may alias the memoized ``_fresh_batch1`` tree's
        buffer/length leaves safely.
        """
        fn = self._suffix_fns.get(n_pre_chunks)
        if fn is None:
            start = n_pre_chunks * self.ecfg.policy.buffer_size
            fn = jax.jit(
                lambda p, b, c1: self.model.prefill_suffix(
                    p, b, c1, start, self.ecfg.policy, self._cap(),
                    fused=self.ecfg.fused))
            self._suffix_fns[n_pre_chunks] = fn
        return fn

    def _extract_fn(self, c_lo: int, c_hi: int):
        """Jitted chunk extraction from a batch-1 cache tree."""
        fn = self._extract_fns.get((c_lo, c_hi))
        if fn is None:
            fn = jax.jit(lambda caches: pc_store.extract_tree_chunks(
                self._cache_cfgs, caches, c_lo, c_hi))
            self._extract_fns[(c_lo, c_hi)] = fn
        return fn

    def reset_slot(self, caches, slot: int):
        """Return ``caches`` with batch row ``slot`` cleared to empty state."""
        return self._splice(caches, self._fresh_batch1(),
                            jnp.asarray(slot, jnp.int32))

    # ------------------------------------------------------------------
    def generate(self, batch: dict, max_new_tokens: int, key=None, active=None):
        """Greedy/sampled wave generation.  Returns (tokens [B, T], stats).

        ``active``: optional bool mask [B] of slots holding real requests;
        padded copy slots are excluded from the throughput accounting.
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        cfg, ecfg = self.cfg, self.ecfg
        t0 = time.time()
        logits, caches = self.prefill(batch)
        t_prefill = time.time() - t0
        prompt_len = self._prompt_len(batch)
        B = logits.shape[0]

        tok = sample(logits[:, -1], key, ecfg.temperature, ecfg.top_k)
        out = [tok]
        done = jnp.zeros(tok.shape[:1], bool)
        t1 = time.time()
        for t in range(max_new_tokens - 1):
            tb = {"tokens": tok[:, None] if cfg.modality != "audio" else tok[:, None, :]}
            # per-slot position vector: the same decode program serves the
            # continuous-batching path, where positions genuinely differ.
            pos = jnp.full((B,), prompt_len + t, jnp.int32)
            logits, caches = self.decode(tb, caches, pos)
            key = jax.random.fold_in(key, t)
            tok = sample(logits[:, -1], key, ecfg.temperature, ecfg.top_k)
            if ecfg.eos_id >= 0:
                done = done | (tok == ecfg.eos_id) if cfg.modality != "audio" else done
                tok = jnp.where(done, ecfg.eos_id, tok) if cfg.modality != "audio" else tok
            out.append(tok)
            if ecfg.eos_id >= 0 and bool(done.all()):
                break
        toks = jnp.stack(out, axis=1)
        t_decode = time.time() - t1
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": self._decode_tok_per_s(toks, t_decode, active),
            "cache_bytes": self.cache_nbytes(caches),
        }
        return toks, stats

    def _decode_tok_per_s(self, toks, t_decode: float, active) -> float:
        """Decode throughput over USEFUL tokens only: padded copy slots
        (``active`` False) and post-EOS / early-exit filler are excluded, so
        bench numbers aren't inflated by throwaway work."""
        tnp = np.asarray(toks)
        B, T = tnp.shape[0], tnp.shape[1]
        act = np.ones(B, bool) if active is None else np.asarray(active, bool)
        n_use = np.full(B, T)
        if self.ecfg.eos_id >= 0 and self.cfg.modality != "audio":
            hit = tnp == self.ecfg.eos_id
            has = hit.any(axis=1)
            n_use[has] = hit.argmax(axis=1)[has] + 1  # keep the EOS itself
        useful_decode = int(np.maximum(n_use - 1, 0)[act].sum())  # 1st tok = prefill
        return useful_decode / max(t_decode, 1e-9)

    def _prompt_len(self, batch) -> int:
        n = batch["tokens"].shape[1]
        if self.cfg.modality == "vlm":
            n += self.cfg.num_prefix_tokens
        return n

    def init_caches(self):
        caches = self.model.init_caches(self.ecfg.policy, self.ecfg.batch, self._cap())
        if self._cache_shard is not None:
            caches = jax.device_put(caches, self._cache_shard)
        return caches

    @staticmethod
    def cache_nbytes(caches) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
