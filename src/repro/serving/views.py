"""Slot-view facade over the engine's cache tree.

:class:`CacheView` is the blessed serving surface for slot-level
continuous batching: it owns one live cache tree and wraps the engine's
slot protocol (``prefill_slot`` / ``reset_slot`` / ``decode``) plus the
admission question (``can_admit``) behind one object, so the scheduler no
longer threads raw cache pytrees through free functions.  The raw-tree
engine methods remain for back-compat, but serving code should go through
a view — it is the only API that works identically for both layouts:

* :class:`DenseCacheView` — per-slot full-capacity arrays; admission is
  slot-count-limited, so ``can_admit`` is always True (a free slot IS the
  capacity).
* :class:`PagedCacheView` — the pooled page layout (DESIGN.md §5):
  ``can_admit`` asks the page allocator whether the request's lifetime
  reservation fits, ``prefill_slot`` right-sizes that reservation with
  ``reserve_tokens``, and ``reclaim`` turns prefix-trie references back
  into allocatable pages when admission deadlocks on an idle engine.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.serving.pagedpool import pages_needed

__all__ = ["CacheView", "DenseCacheView", "PagedCacheView"]


@runtime_checkable
class CacheView(Protocol):
    """One live cache tree + the slot protocol the scheduler drives."""

    caches: Any

    def can_admit(self, n_tokens: int) -> bool:
        """Would a request whose lifetime holds ``n_tokens`` be admitted now?"""
        ...

    def prefill_slot(self, batch1: dict, slot: int, admit: bool = True,
                     reserve_tokens: int | None = None):
        """Prefill one request into ``slot``; returns its last-position
        logits.  ``batch1`` carries the RAW-length prompt (no padding) —
        the engine length-buckets it internally (docs/serving.md §2).
        ``admit=False`` skips inserting the prompt's chunks into the
        prefix trie; ``reserve_tokens`` right-sizes a paged reservation
        to the request's true lifetime instead of full capacity."""
        ...

    def reset_slot(self, slot: int) -> None: ...

    def decode(self, token_batch: dict, pos):
        """One decode step over all slots; returns logits."""
        ...

    def reclaim(self, n_tokens: int) -> bool:
        """Try to free enough backing store to admit ``n_tokens``; True if
        ``can_admit`` now holds."""
        ...

    def audit(self) -> dict:
        """Backing-store invariant report ``{"ok", "issues", ...}``; never
        raises (the chaos suite asserts on it after fault schedules)."""
        ...


class _ViewBase:
    def __init__(self, engine, caches):
        self.engine = engine
        self.caches = caches

    def prefill_slot(self, batch1: dict, slot: int, admit: bool = True,
                     reserve_tokens: int | None = None):
        logits, self.caches = self.engine.prefill_slot(
            batch1, self.caches, slot, admit=admit,
            reserve_tokens=reserve_tokens)
        return logits

    def reset_slot(self, slot: int) -> None:
        self.caches = self.engine.reset_slot(self.caches, slot)

    def decode(self, token_batch: dict, pos):
        logits, self.caches = self.engine.decode(
            token_batch, self.caches, jnp.asarray(pos, jnp.int32))
        return logits


class DenseCacheView(_ViewBase):
    """Dense per-slot layout: a free slot always has full capacity."""

    def can_admit(self, n_tokens: int) -> bool:
        return True

    def reclaim(self, n_tokens: int) -> bool:
        return False           # nothing to reclaim; admission never fails

    def audit(self) -> dict:
        return self.engine.audit()


class PagedCacheView(_ViewBase):
    """Pooled page layout: admission is pool-bytes-limited.

    ``can_admit`` is conservative — it prices the request with zero prefix
    sharing (hits only shrink the fresh-page need), so a True answer
    guarantees :meth:`prefill_slot` will not raise
    :class:`~repro.serving.pagedpool.PoolExhausted`.
    """

    def _pages(self, n_tokens: int) -> int:
        return pages_needed(n_tokens, self.engine.ecfg.policy.buffer_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.engine.pool.can_admit(self._pages(n_tokens))

    def reclaim(self, n_tokens: int) -> bool:
        deficit = self._pages(n_tokens) - self.engine.pool.free_pages
        if deficit > 0:
            self.engine.reclaim_pages(deficit)
        return self.can_admit(n_tokens)

    def audit(self) -> dict:
        return self.engine.audit()
