"""Public serving API.

The blessed surface for building a GEAR-compressed serving stack — entry
points (``repro.launch.serve``), benchmarks, and downstream users import
from here rather than from the submodules:

* :class:`Engine` / :class:`EngineConfig` with the typed knobs
  :class:`AttendPath`, :class:`PrefillMode`, :class:`CacheLayout`
  (plain strings still coerce, as a deprecation shim);
* :class:`Scheduler` with :class:`Request` / :class:`Result` — wave and
  continuous batching;
* :class:`CacheView` (:class:`DenseCacheView` / :class:`PagedCacheView`)
  — the slot-protocol facade the scheduler drives;
* the paged pool primitives (:class:`PagePool`, :class:`PagePoolStore`,
  :class:`PoolExhausted`, :func:`pages_needed`) for tooling that inspects
  admission state;
* the resilience layer (docs/serving.md §4): :class:`RequestStatus` /
  :class:`RetryPolicy` / :class:`AdmissionValve` lifecycle primitives,
  :class:`NumericFault` quarantine, and the chaos-test harness
  (:class:`FaultInjector`, :class:`FaultEvent`, :class:`FakeClock`,
  :class:`InjectedFault`);
* the observability layer (docs/observability.md): :class:`ObsConfig` /
  :class:`Observability` (``EngineConfig(obs=...)``), plus the typed
  :class:`PoolSnapshot` / :class:`PrefixSnapshot` stats views that
  ``Scheduler.last_stats`` carries.
"""

from repro.core.cache import NumericFault
from repro.obs import Observability, ObsConfig
from repro.prefixcache import PrefixSnapshot
from repro.serving.engine import (AttendPath, CacheLayout, Engine,
                                  EngineConfig, PrefillMode,
                                  prefix_cache_unsupported_reason)
from repro.serving.faults import (FakeClock, FaultEvent, FaultInjector,
                                  InjectedFault)
from repro.serving.pagedpool import (PagePool, PagePoolStore, PoolExhausted,
                                     PoolSnapshot, pages_needed)
from repro.serving.resilience import AdmissionValve, RequestStatus, RetryPolicy
from repro.serving.sampling import sample
from repro.serving.scheduler import Request, Result, Scheduler
from repro.serving.views import CacheView, DenseCacheView, PagedCacheView

__all__ = [
    "AttendPath", "PrefillMode", "CacheLayout",
    "Engine", "EngineConfig", "prefix_cache_unsupported_reason",
    "Scheduler", "Request", "Result",
    "CacheView", "DenseCacheView", "PagedCacheView",
    "PagePool", "PagePoolStore", "PoolExhausted", "pages_needed",
    "RequestStatus", "RetryPolicy", "AdmissionValve", "NumericFault",
    "FaultInjector", "FaultEvent", "FakeClock", "InjectedFault",
    "ObsConfig", "Observability", "PoolSnapshot", "PrefixSnapshot",
    "sample",
]
