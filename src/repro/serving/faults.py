"""Seeded, deterministic fault injection for the serving stack.

The chaos harness behind tests/test_chaos.py and ``bench_throughput
--chaos``: a :class:`FaultInjector` fires faults at well-defined **sites**
in the request lifecycle, either probabilistically (seeded per-site RNGs,
so one site's rate never perturbs another's stream) or at exact visit
indices (:class:`FaultEvent` schedules).  The same ``(seed, rates,
schedule)`` triple always produces the same fault sequence for the same
workload — which is what lets the chaos properties compare a faulty run
against its fault-free twin token-for-token.

Sites and what firing does:

* ``pool_exhausted`` — :meth:`~repro.serving.pagedpool.PagePool.admit`
  raises :class:`~repro.serving.pagedpool.PoolExhausted` with no state
  change, exercising the scheduler's bounded-retry / rejection path.
* ``nan_chunk`` — the batch-1 prefill's cache tree gets one NaN written
  into its first float leaf before the engine's numeric guard runs,
  exercising quarantine (:class:`~repro.core.cache.NumericFault` →
  ``FAILED`` for that request only).
* ``prefill_error`` / ``decode_error`` — an :class:`InjectedFault` is
  raised *before* the jitted step is dispatched (so no donated buffer is
  ever consumed by a failed call), exercising step-retry and the
  all-active-``FAILED`` abort.
* ``clock_skew`` — the injector's :class:`FakeClock` jumps forward by
  ``skew_s``, expiring prefix-cache TTLs mid-run.
* ``trie_evict`` — the engine's prefix cache is force-evicted down to
  nothing (pinned paths survive, by the trie's refcount rules),
  exercising eviction-mid-flight.

The injector is attached by the scheduler (``Scheduler(engine,
faults=...)``), which wires the engine and its page pool; nothing in the
production path references this module unless an injector is attached.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import NumericFault

__all__ = ["FAULT_SITES", "FakeClock", "FaultEvent", "FaultInjector",
           "InjectedFault", "NumericFault"]

FAULT_SITES = ("pool_exhausted", "nan_chunk", "prefill_error", "decode_error",
               "clock_skew", "trie_evict")


class InjectedFault(RuntimeError):
    """A deliberately-raised engine-step fault (transient by construction).

    Distinct from real error types so production handlers can never
    confuse a chaos-test fault with an organic failure; the scheduler
    treats it like any transient engine-step exception (bounded retry,
    then ``FAILED``).
    """

    def __init__(self, msg: str, site: str = ""):
        super().__init__(msg)
        self.site = site


class FakeClock:
    """Injectable monotonic-seconds source whose ``sleep`` advances time.

    Drop-in for the trie's ``clock`` knob, the scheduler's ``clock`` /
    ``sleep`` pair, and the injector's skew target — one instance shared
    across all three makes TTL expiry, deadlines, and backoff waits
    deterministic in tests (no real sleeping, no wall-clock flake).
    """

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.t += dt

    def sleep(self, dt: float) -> None:
        self.advance(max(float(dt), 0.0))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """Fire ``site`` deterministically on its ``at``-th visit (0-based)."""

    site: str
    at: int

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites: {FAULT_SITES}")
        if self.at < 0:
            raise ValueError(f"event index must be >= 0, got {self.at}")


class FaultInjector:
    """Deterministic fault source: per-site seeded rates + exact schedules.

    ``rates`` maps site name → per-visit fire probability; ``schedule`` is
    a sequence of :class:`FaultEvent` firing at exact visit indices
    (schedules and rates compose — a visit fires if either says so).
    Each site draws from its own ``RandomState`` seeded by ``(seed,
    site_index)``, so enabling one site never shifts another site's
    stream.  ``fired`` / ``visits`` counters and the ``log`` of
    ``(site, visit_index)`` firings make every chaos run auditable.
    """

    def __init__(self, seed: int = 0,
                 rates: dict[str, float] | None = None,
                 schedule: Sequence[FaultEvent] = (),
                 clock: FakeClock | None = None,
                 skew_s: float = 3600.0,
                 evict_bytes: int = 1 << 62):
        rates = dict(rates or {})
        unknown = set(rates) - set(FAULT_SITES)
        if unknown:
            raise ValueError(f"unknown fault sites {sorted(unknown)}; "
                             f"sites: {FAULT_SITES}")
        for site, rate in rates.items():
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], got {rate}")
        self.rates = rates
        self._sched: dict[str, set[int]] = {s: set() for s in FAULT_SITES}
        for ev in schedule:
            self._sched[ev.site].add(ev.at)
        self._rngs = {site: np.random.RandomState([int(seed) & 0x7FFFFFFF, i])
                      for i, site in enumerate(FAULT_SITES)}
        self.clock = clock
        self.skew_s = float(skew_s)
        self.evict_bytes = int(evict_bytes)
        self.visits = {s: 0 for s in FAULT_SITES}
        self.fired = {s: 0 for s in FAULT_SITES}
        self.log: list[tuple[str, int]] = []
        # telemetry sink: Engine.attach_faults points this at its
        # Observability so firings become counter increments and trace
        # events; None keeps the injector dependency-free
        self.obs = None

    def fire(self, site: str) -> bool:
        """One visit to ``site``; True when a fault should fire now."""
        i = self.visits[site]
        self.visits[site] = i + 1
        rate = self.rates.get(site, 0.0)
        hit = i in self._sched[site]
        if rate > 0.0:
            # always consume the draw so the stream is schedule-independent
            hit = bool(self._rngs[site].random_sample() < rate) or hit
        if hit:
            self.fired[site] += 1
            self.log.append((site, i))
            if self.obs is not None:
                self.obs.fault_fired(site, i)
        return hit

    # -- site hooks ---------------------------------------------------------
    def on_admit(self, slot: int) -> None:
        """Called by :meth:`PagePool.admit` before any state change."""
        if self.fire("pool_exhausted"):
            from repro.serving.pagedpool import PoolExhausted
            raise PoolExhausted(f"slot {slot}: injected pool exhaustion")

    def check_step(self, which: str) -> None:
        """Called by the scheduler before dispatching a prefill/decode step."""
        if self.fire(f"{which}_error"):
            raise InjectedFault(f"injected {which} engine-step fault",
                                site=f"{which}_error")

    def corrupt_tree(self, tree: Any) -> Any:
        """NaN-poison the first float leaf of a batch-1 cache tree.

        Called by the engine between the prefill and its numeric guard —
        the poisoned tree is exactly what a corrupted compression event
        would have produced, so the guard (not the injector) decides the
        request's fate.
        """
        if not self.fire("nan_chunk"):
            return tree
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        for i, leaf in enumerate(leaves):
            if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.inexact):
                idx = tuple(0 for _ in leaf.shape)
                leaves[i] = leaf.at[idx].set(jnp.nan)
                break
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def tick(self, engine) -> None:
        """Per-scheduler-iteration environmental faults (skew, eviction)."""
        if self.clock is not None and self.fire("clock_skew"):
            self.clock.advance(self.skew_s)
        pc = getattr(engine, "prefix_cache", None)
        if pc is not None and self.fire("trie_evict"):
            pc.evict_bytes(self.evict_bytes)
