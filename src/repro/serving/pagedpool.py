"""Global pool of compressed-chunk pages + refcounted block tables.

The paged serving layout (DESIGN.md §5) splits the compressed KV state
into fixed-size **pages** — one page holds one ``n_b``-token GEAR chunk's
packed codes / quant stats / low-rank factors / outliers for one layer
(every layer's pool shares the same page ids, so "page p" is one chunk's
worth of state *across the whole model* and its byte cost is the sum over
layers).  Device arrays live in the engine cache tree
(:class:`repro.core.cache.PagedGEARLayerCache` leaves); this module owns
the **host-side allocator**: the free list, per-page reference counts, and
the per-slot block-table mirror the engine pushes to the device at
admission/release.

Why refcounts make prefix sharing free: closed GEAR chunks are immutable
(decode writes only the page of the chunk currently being closed, which is
always freshly allocated to that slot), so two slots whose block tables
point at the same prefix page never conflict — copy-on-write degenerates
to pure reference counting and *no page is ever copied*.  The radix trie
(:mod:`repro.prefixcache`) holds a reference on every page it indexes
(:class:`PagePoolStore`), so a cached prefix survives its creator slot.

The zero-page invariant: page 0 is reserved, permanently zero, and never
allocated; block-table rows reset to 0 and fresh pages are zeroed at
admission (:func:`repro.core.cache.zero_pool_pages`), so any table entry a
kernel reads past a slot's live extent streams the same zero bytes the
dense layout holds there — the invariant behind the paged ≡ dense
bit-identity guarantee.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["PagePool", "PagePoolStore", "PoolExhausted", "PoolSnapshot",
           "pages_needed"]


@dataclasses.dataclass(frozen=True)
class PoolSnapshot:
    """Typed point-in-time view of a :class:`PagePool` — the ``"pool"``
    entry in ``Scheduler.last_stats``.  Indexing (``snap["admits"]``)
    delegates to attributes so legacy dict-style consumers keep working.
    """

    admits: int
    rejects: int
    shared_pages: int
    fresh_pages: int
    freed_pages: int
    page_bytes: int
    free_pages: int
    used_pages: int
    total_bytes: int
    used_bytes: int

    def __getitem__(self, key: str):
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def pages_needed(n_tokens: int, chunk: int) -> int:
    """Pages a request holding up to ``n_tokens`` needs: one per started
    chunk.  The trailing partial chunk lives in the per-slot FP16 streaming
    buffer, not a page — but a request is budgeted for its whole lifetime
    (prompt + generation), so admission rounds up."""
    return (n_tokens + chunk - 1) // chunk


class PoolExhausted(RuntimeError):
    """Admission failed: fewer free pages than the request's reservation.

    Deliberately a distinct type so the scheduler can treat it as "queue
    and retry after something releases", never as a crash.
    """


class PagePool:
    """Host-side page allocator for one engine's paged cache tree.

    ``n_pages`` counts page 0 (the reserved zero page), so ``n_pages - 1``
    pages are allocatable.  ``page_bytes`` is the all-layers byte cost of
    one page (engine computes it from the cache geometry) — the pool's
    byte accounting is exact by construction: ``used_bytes == live pages ×
    page_bytes``.

    Reference counts: a page's count is the number of slot block tables
    currently containing it plus the number of prefix-trie handles
    retaining it (:class:`PagePoolStore`).  ``admit`` bumps shared pages
    and allocates the rest fresh at count 1; ``release_slot`` decrements a
    slot's whole row; a count hitting zero returns the page to the free
    list.  Freed pages are NOT zeroed — the zero-page invariant is
    restored at the next admission (fresh pages are zeroed before the
    block table exposes them), which keeps release device-work-free.
    """

    def __init__(self, n_pages: int, batch: int, n_chunks: int,
                 page_bytes: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 reserved), got {n_pages}")
        self.n_pages = n_pages
        self.batch = batch
        self.n_chunks = n_chunks
        self.page_bytes = page_bytes
        self._free: list[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1 first
        self._refs = np.zeros(n_pages, np.int64)
        self._refs[0] = 1                      # zero page: never allocatable
        # host mirror of the device block tables; row b all-zero == idle slot
        self.block_tables = np.zeros((batch, n_chunks), np.int32)
        self._slot_n = np.zeros(batch, np.int64)   # pages held per slot
        self.stats = {"admits": 0, "rejects": 0, "shared_pages": 0,
                      "fresh_pages": 0, "freed_pages": 0}
        # chaos hook: a FaultInjector (serving/faults.py) whose on_admit
        # may raise PoolExhausted before any state change; None in prod
        self.faults = None

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    @property
    def total_bytes(self) -> int:
        return (self.n_pages - 1) * self.page_bytes

    @property
    def used_bytes(self) -> int:
        return self.used_pages * self.page_bytes

    def can_admit(self, n_total: int, n_shared: int = 0) -> bool:
        """True when a reservation of ``n_total`` pages (``n_shared`` of
        them prefix-cache hits needing no allocation) would succeed."""
        return (n_total - n_shared) <= len(self._free) and n_total <= self.n_chunks

    def snapshot(self) -> PoolSnapshot:
        """Typed snapshot of lifetime counters + current occupancy."""
        return PoolSnapshot(
            admits=self.stats["admits"], rejects=self.stats["rejects"],
            shared_pages=self.stats["shared_pages"],
            fresh_pages=self.stats["fresh_pages"],
            freed_pages=self.stats["freed_pages"],
            page_bytes=self.page_bytes, free_pages=self.free_pages,
            used_pages=self.used_pages, total_bytes=self.total_bytes,
            used_bytes=self.used_bytes)

    # -- slot lifecycle ----------------------------------------------------
    def admit(self, slot: int, n_total: int,
              shared: Sequence[int] = ()) -> np.ndarray:
        """Reserve ``n_total`` pages for ``slot``: the leading
        ``len(shared)`` entries reuse the given (prefix-cache) pages with a
        refcount bump, the rest are allocated fresh.  Returns the pages
        newly allocated (the ones the engine must zero on device before
        pushing the table row).  Raises :class:`PoolExhausted` when the
        free list is short — state unchanged, safe to retry later.
        """
        shared = list(shared)
        if self._slot_n[slot]:
            raise RuntimeError(f"slot {slot} already admitted; release first")
        if len(shared) > n_total:
            raise ValueError(f"{len(shared)} shared pages > total {n_total}")
        if n_total > self.n_chunks:
            raise ValueError(
                f"request needs {n_total} pages but the block table has "
                f"{self.n_chunks} chunk entries (capacity bound)")
        if self.faults is not None:
            try:
                self.faults.on_admit(slot)
            except PoolExhausted:
                self.stats["rejects"] += 1
                raise
        n_fresh = n_total - len(shared)
        if n_fresh > len(self._free):
            self.stats["rejects"] += 1
            raise PoolExhausted(
                f"slot {slot}: need {n_fresh} fresh pages, {len(self._free)} free")
        for p in shared:
            if self._refs[p] <= 0:
                raise RuntimeError(f"shared page {p} is not live")
        fresh = [self._free.pop() for _ in range(n_fresh)]
        for p in shared:
            self._refs[p] += 1
        for p in fresh:
            self._refs[p] = 1
        row = self.block_tables[slot]
        row[:] = 0
        row[:n_total] = shared + fresh
        self._slot_n[slot] = n_total
        self.stats["admits"] += 1
        self.stats["shared_pages"] += len(shared)
        self.stats["fresh_pages"] += n_fresh
        return np.asarray(fresh, np.int32)

    def release_slot(self, slot: int) -> list[int]:
        """Drop the slot's reference on every page in its block-table row
        and clear the row.  Returns the pages whose count hit zero (now
        back on the free list) — informational; the engine does no device
        work for them (zero-at-admit invariant)."""
        n = int(self._slot_n[slot])
        freed = []
        for p in self.block_tables[slot, :n]:
            if self._release_page(int(p)):
                freed.append(int(p))
        self.block_tables[slot] = 0
        self._slot_n[slot] = 0
        self.stats["freed_pages"] += len(freed)
        return freed

    def slot_pages(self, slot: int) -> np.ndarray:
        return self.block_tables[slot, : int(self._slot_n[slot])].copy()

    # -- prefix-cache handles ---------------------------------------------
    def retain(self, page: int) -> int:
        """Take an extra reference (trie insertion).  Returns the page."""
        if self._refs[page] <= 0:
            raise RuntimeError(f"retain of dead page {page}")
        self._refs[page] += 1
        return page

    def release(self, page: int) -> bool:
        """Drop one reference (trie eviction).  True if the page was freed."""
        freed = self._release_page(page)
        if freed:
            self.stats["freed_pages"] += 1
        return freed

    def _release_page(self, page: int) -> bool:
        if page == 0:
            return False                        # zero page is permanent
        if self._refs[page] <= 0:
            raise RuntimeError(f"double free of page {page}")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            return True
        return False

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def check(self) -> None:
        """Invariant audit (tests): every page is exactly free or live,
        and live counts equal table occurrences + store retains."""
        rep = self.audit()
        assert rep["ok"], rep["issues"]

    def audit(self, retained: Sequence[int] | None = None) -> dict:
        """Structural invariant audit; returns a report, never raises.

        Always checked: the free list has no duplicates and never holds
        page 0; every page is *exactly* one of free or live (refcount >
        0); every block-table entry within a slot's extent is live;
        entries past the extent are 0.  When ``retained`` — the full
        multiset of pages the prefix trie currently holds handles on — is
        supplied, refcounts are checked *exactly*: each page's count must
        equal its block-table occurrences plus its retained-handle count,
        and any live page with neither is reported in ``leaked_pages``.
        Without ``retained`` (callers that cannot see the trie), only the
        structural invariants run.
        """
        issues: list[str] = []
        free = set(self._free)
        if 0 in free:
            issues.append("zero page on the free list")
        if len(free) != len(self._free):
            issues.append("free list has duplicates")
        for p in range(1, self.n_pages):
            live = self._refs[p] > 0
            if self._refs[p] < 0:
                issues.append(f"page {p}: negative refcount {self._refs[p]}")
            if live == (p in free):
                issues.append(f"page {p}: refs={self._refs[p]} free={p in free}")
        table_occ = np.zeros(self.n_pages, np.int64)
        for b in range(self.batch):
            n = int(self._slot_n[b])
            for p in self.block_tables[b, :n]:
                p = int(p)
                if not 0 <= p < self.n_pages:
                    issues.append(f"slot {b}: table entry {p} out of range")
                    continue
                table_occ[p] += 1
                if p != 0 and self._refs[p] <= 0:
                    issues.append(f"slot {b}: dead page {p} in block table")
            if np.any(self.block_tables[b, n:] != 0):
                issues.append(f"slot {b}: nonzero table entries past extent {n}")
        leaked: list[int] = []
        if retained is not None:
            held = np.zeros(self.n_pages, np.int64)
            for p in retained:
                held[int(p)] += 1
            for p in range(1, self.n_pages):
                expect = int(table_occ[p] + held[p])
                if int(self._refs[p]) != expect:
                    issues.append(f"page {p}: refs={int(self._refs[p])} but "
                                  f"tables+handles={expect}")
                if self._refs[p] > 0 and expect == 0:
                    leaked.append(p)
        return {"ok": not issues, "issues": issues, "leaked_pages": leaked,
                "free_pages": len(self._free), "used_pages": self.used_pages}


class PagePoolStore:
    """Chunk-store adapter making pool pages the prefix-cache payload.

    Drop-in for :class:`repro.prefixcache.store.ChunkStore`: a payload
    handle IS a page id.  ``put`` takes the trie's reference on the page
    (it must already be live — the admitting slot holds it), ``free``
    releases it, ``get`` returns the page id for the engine to gather
    device-side.  ``nbytes_of`` prices every handle at the pool's exact
    page cost, so the trie's LRU byte budget governs real pool bytes.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._count = 0

    def __len__(self) -> int:
        return self._count

    @property
    def total_bytes(self) -> int:
        return self._count * self.pool.page_bytes

    def put(self, page: int) -> int:
        handle = self.pool.retain(int(page))
        self._count += 1
        return handle

    def get(self, handle: int) -> int:
        return handle

    def free(self, handle: int) -> None:
        self.pool.release(int(handle))
        self._count -= 1

    def nbytes_of(self, payload) -> int:
        return self.pool.page_bytes
