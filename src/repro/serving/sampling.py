"""Token sampling for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample"]


def sample(logits: jnp.ndarray, key: jax.Array, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits [..., V] -> token ids [...]. temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(lg, axis=-1)[..., -top_k][..., None]
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
