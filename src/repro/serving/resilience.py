"""Request-lifecycle resilience primitives for the serving layer.

GEAR's serving stack promises *near-lossless* numerics; this module is
about what happens when the numerics — or the capacity planning — go
wrong.  It defines the typed request-terminal states and the two knobs the
scheduler uses to turn unbounded failure loops into bounded, observable
outcomes:

* :class:`RequestStatus` — every submitted request terminates with exactly
  one :class:`~repro.serving.scheduler.Result` carrying one of these
  statuses.  ``OK`` and ``DEGRADED`` results carry bit-exact tokens (a
  retried or fault-adjacent request is *slower*, never *different* — the
  splice-isolation guarantee survives faults); ``TIMEOUT`` carries the
  tokens generated before the deadline; ``REJECTED`` / ``FAILED`` carry
  whatever partial output existed when the request was terminated.

* :class:`RetryPolicy` — bounded admission retries with exponential
  backoff.  A transient :class:`~repro.serving.pagedpool.PoolExhausted`
  (or an injected engine-step fault) requeues the request at most
  ``max_attempts`` times; past that the scheduler surfaces a terminal
  ``REJECTED`` (capacity) / ``FAILED`` (fault) result instead of spinning.
  Backoff waits run on the scheduler's injectable clock/sleep pair, so
  chaos tests drive them with a :class:`~repro.serving.faults.FakeClock`.

* :class:`AdmissionValve` — load shedding at submit time: beyond
  ``max_queue`` waiting requests, new submissions are immediately recorded
  as ``REJECTED`` results (delivered by the next run) rather than queued
  behind work that cannot complete in time.

See docs/serving.md §4 ("Failure modes & degradation") for the operator
view and tests/test_chaos.py for the invariants these must uphold.
"""

from __future__ import annotations

import dataclasses
import enum

__all__ = ["RequestStatus", "RetryPolicy", "AdmissionValve"]


class RequestStatus(str, enum.Enum):
    """Terminal state of one served request.

    ``OK``        — completed; tokens are bit-identical to a solo run.
    ``DEGRADED``  — completed with bit-identical tokens, but service was
                    impaired en route: admission needed more than one
                    attempt, or a decode step the request was part of hit
                    an (injected) engine fault and was retried.  The
                    status flags the SLO impact; the payload is exact.
    ``TIMEOUT``   — the request's ``deadline_s`` elapsed; the result keeps
                    the tokens generated before the cutoff (possibly none,
                    if the deadline passed while still queued).
    ``REJECTED``  — never admitted: the load-shedding valve shed it at
                    submit, or admission exhausted ``RetryPolicy.max_attempts``
                    under sustained pool pressure.
    ``FAILED``    — terminated by a fault: a NaN/Inf-poisoned compressed
                    chunk (numeric quarantine), or repeated engine-step
                    exceptions.  The slot was reset and its pages
                    released; co-batched requests are unaffected.
    """

    OK = "ok"
    TIMEOUT = "timeout"
    REJECTED = "rejected"
    DEGRADED = "degraded"
    FAILED = "failed"

    __str__ = str.__str__


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded-retry + exponential-backoff policy for admission failures.

    ``max_attempts`` caps how many times one request's admission (or one
    batched decode step) may fail before the scheduler surfaces a terminal
    status.  ``backoff_s`` is the wait after the first failure, multiplied
    by ``backoff_mult`` per subsequent failure and clamped to
    ``max_backoff_s``; the default ``backoff_s=0`` keeps the fault-free
    hot path free of sleeps (retries ride the natural decode-step cadence).
    """

    max_attempts: int = 3
    backoff_s: float = 0.0
    backoff_mult: float = 2.0
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_s < 0 or self.max_backoff_s < 0 or self.backoff_mult < 1:
            raise ValueError("backoff knobs must be non-negative (mult >= 1)")

    def backoff(self, attempt: int) -> float:
        """Seconds to wait after failed attempt number ``attempt`` (1-based)."""
        if self.backoff_s <= 0.0:
            return 0.0
        return min(self.backoff_s * self.backoff_mult ** max(attempt - 1, 0),
                   self.max_backoff_s)


@dataclasses.dataclass(frozen=True)
class AdmissionValve:
    """Submit-time load shedding.

    ``max_queue`` bounds the scheduler's wait queue: a submit that would
    make the queue longer is recorded as an immediate ``REJECTED`` result
    (delivered with the next run's results) instead of being enqueued.
    ``None`` disables shedding.  Shedding at submit — rather than deep in
    the admission loop — keeps rejection latency flat under overload: the
    caller learns immediately, and queued requests' wait times stay
    bounded by queue length × service time.
    """

    max_queue: int | None = None

    def shed(self, queue_len: int) -> bool:
        """True when a new submission should be rejected at depth ``queue_len``."""
        return self.max_queue is not None and queue_len >= self.max_queue
