"""Request-level batching scheduler on top of the engine.

Wave-based continuous batching: pending requests are padded/grouped into
fixed-size waves (the engine's static batch), each wave generates until
every member hits EOS or its token budget, finished slots return results
and the next wave starts.  Straggler mitigation at this level is budget
capping — a slot can never hold a wave longer than ``max_new_tokens``.

(True slot-level continuous batching — splicing a new request into a live
batch — requires per-slot cache re-prefill; the cache layout supports it
(all per-slot state is batch-dim addressable) and it is left as an
extension point, documented in DESIGN.md.)
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine

__all__ = ["Request", "Result", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # [prompt_len] int32
    max_new_tokens: int = 64


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray            # generated ids
    prefill_s: float
    decode_s: float


class Scheduler:
    def __init__(self, engine: Engine, prompt_pad: int):
        self.engine = engine
        self.prompt_pad = prompt_pad
        self.queue: deque[Request] = deque()

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> list[Result]:
        """Drain the queue in engine-batch-sized waves."""
        results: list[Result] = []
        B = self.engine.ecfg.batch
        while self.queue:
            wave = [self.queue.popleft() for _ in range(min(B, len(self.queue)))]
            while len(wave) < B:                      # pad with a copy slot
                wave.append(Request(rid=-1, tokens=wave[0].tokens,
                                    max_new_tokens=wave[0].max_new_tokens))
            prompts = np.stack([_pad(r.tokens, self.prompt_pad) for r in wave])
            budget = max(r.max_new_tokens for r in wave)
            toks, stats = self.engine.generate(
                {"tokens": jnp.asarray(prompts, jnp.int32)}, budget)
            toks = np.asarray(toks)
            for i, r in enumerate(wave):
                if r.rid < 0:
                    continue
                results.append(Result(rid=r.rid, tokens=toks[i, : r.max_new_tokens],
                                      prefill_s=stats["prefill_s"],
                                      decode_s=stats["decode_s"]))
        return results


def _pad(tokens: np.ndarray, length: int) -> np.ndarray:
    if len(tokens) >= length:
        return tokens[-length:]
    return np.pad(tokens, (length - len(tokens), 0))
