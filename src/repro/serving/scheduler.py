"""Request-level batching scheduler on top of the engine.

Two scheduling modes over the engine's static batch of B *slots*:

* :meth:`Scheduler.run` — wave batching: pending requests are padded into
  fixed-size waves, each wave generates until every member hits EOS or the
  wave's max budget, then the next wave starts.  Simple, but every slot is
  held hostage by the slowest request in its wave.

* :meth:`Scheduler.run_continuous` — slot-level continuous batching: a
  step-loop decodes all B slots each step with per-slot position/done/budget
  vectors; the moment a slot's request hits its own EOS or budget, the next
  queued request is spliced into that slot (batch-1 prefill →
  :meth:`Engine.prefill_slot` batch-row write) while the other slots keep
  decoding undisturbed.  Splice isolation — a spliced request produces
  bit-identical greedy tokens to a solo run — is guaranteed by the per-slot
  cache layout and batch-invariant compression (see DESIGN.md).  Since the
  fused GEAR decode kernel is ragged-aware (per-slot masking inside the
  kernel), mixed-length continuous batches run the same fused
  ``gear_attend`` path as wave mode — ``last_stats["attend_path"]`` reports
  which path the engine compiled.

Both modes trim each request's results at its own first EOS and report
per-request prefill/decode latency.  When the engine has a prefix cache
(``EngineConfig.prefix_cache``), continuous mode threads the scheduler's
admission policy into every slot prefill and reports ``prefix_hit_rate`` /
``prefill_toks_saved`` in ``last_stats``.

**Raw prompts, no scheduler padding.**  Continuous mode hands each
request's RAW token list to :meth:`Engine.prefill_slot`: the engine
length-buckets the prompt up to the next ``n_b`` multiple internally
(bounding jit recompilation to one program per bucket) while cache
lengths, logits, and prefix-trie keys all reflect the true length.  The
trie therefore keys on raw ``n_b``-aligned token chunks, so requests of
*different* lengths sharing a chunk-aligned prefix (the mixed-length
shared-system-prompt workload) hit each other's chunks — see
docs/serving.md and DESIGN.md §4.  Wave mode still left-pads, but only to
the longest raw prompt *within each wave* (a whole wave shares one prefill
program); mixed-length waves therefore shift chunk boundaries per wave —
use continuous mode when prefix reuse or per-request numeric
reproducibility across batch compositions matters.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import NumericFault
from repro.serving.engine import Engine
from repro.serving.faults import InjectedFault
from repro.serving.pagedpool import PoolExhausted, pages_needed
from repro.serving.resilience import AdmissionValve, RequestStatus, RetryPolicy
from repro.serving.sampling import sample

__all__ = ["Request", "Result", "Scheduler"]

_EMPTY = np.zeros(0, np.int32)


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # [prompt_len] int32
    max_new_tokens: int = 64
    # seconds from submit until the request times out (scheduler clock);
    # None = no deadline.  A queued request past its deadline is dropped
    # with an empty TIMEOUT result; a running one keeps the tokens it
    # generated before the cutoff.
    deadline_s: float | None = None


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray            # generated ids, truncated at first EOS
    prefill_s: float
    decode_s: float
    # typed terminal state (resilience layer, docs/serving.md §4); OK and
    # DEGRADED both carry bit-identical tokens — DEGRADED only flags that
    # service was impaired (admission retried / a decode step was retried)
    status: RequestStatus = RequestStatus.OK
    attempts: int = 1             # admission attempts consumed (1 = clean)
    error: str = ""               # human-readable cause for non-OK statuses


class Scheduler:
    """Request queue + batching policy over one :class:`Engine`.

    Construct with the engine and (optionally) the prefix-cache admission
    policy, :meth:`submit` requests, then drain with :meth:`run` (wave
    batching) or :meth:`run_continuous` (slot-level continuous batching —
    the recommended mode; see the module docstring).  Per-run aggregate
    metrics land in :attr:`last_stats`.

    ``prefix_admission`` is threaded to :meth:`Engine.prefill_slot` when
    the engine has a prefix cache: "all" inserts every request's newly
    closed prompt chunks into the trie; "off" reuses cached prefixes but
    admits nothing new (e.g. a bursty one-off workload that would churn
    the eviction budget).

    Resilience knobs (docs/serving.md §4):

    * ``retry`` — :class:`~repro.serving.resilience.RetryPolicy` bounding
      admission retries under pool pressure (and decode-step fault
      retries) with exponential backoff; past the cap the request gets a
      terminal ``REJECTED`` (capacity) / ``FAILED`` (fault) result
      instead of spinning.
    * ``valve`` — :class:`~repro.serving.resilience.AdmissionValve` load
      shedding at :meth:`submit`: beyond ``max_queue`` waiting requests,
      submissions are recorded as immediate ``REJECTED`` results.
    * ``faults`` — a :class:`~repro.serving.faults.FaultInjector`; the
      scheduler wires it into the engine + pool hooks and drives its
      per-iteration environmental faults.  Never set in production.
    * ``clock`` / ``sleep`` — injectable monotonic-seconds source and
      sleeper for deadlines and backoff waits (default: the injector's
      FakeClock when it has one, else ``time.monotonic``/``time.sleep``);
      wall-clock *stats* always use real time.
    """

    def __init__(self, engine: Engine, prefix_admission: str = "all",
                 retry: RetryPolicy | None = None,
                 valve: AdmissionValve | None = None,
                 faults=None, clock=None, sleep=None):
        if prefix_admission not in ("all", "off"):
            raise ValueError(
                f"prefix_admission must be all/off, got {prefix_admission!r}")
        self.engine = engine
        self.prefix_admission = prefix_admission
        self.retry = RetryPolicy() if retry is None else retry
        self.valve = AdmissionValve() if valve is None else valve
        self._faults = faults
        if faults is not None:
            engine.attach_faults(faults)
            if clock is None:
                clock = faults.clock
        self._clock = time.monotonic if clock is None else clock
        self._sleep = (sleep if sleep is not None
                       else getattr(clock, "sleep", time.sleep))
        # telemetry hub (repro.obs), engine-owned; the tracer follows the
        # scheduler's clock so spans line up with deadlines/backoff (and
        # stay deterministic under a FakeClock)
        self.obs = getattr(engine, "obs", None)
        if self.obs is not None:
            self.obs.tracer.clock = self._clock
        self.queue: deque[Request] = deque()
        self.last_stats: dict = {}
        self.submitted_rids: list[int] = []
        self._submit_t: dict[int, float] = {}
        self._shed: list[Result] = []

    def _need_tokens(self, req: Request) -> int:
        """Cache tokens a request's whole lifetime holds: its raw prompt
        (+ VLM prefix) plus one appended token per decode step (the first
        generated token comes from prefill).  True lifetime — paged
        admission reserves exactly these pages, so shorter prompts really
        do cost fewer pages."""
        prefix = (self.engine.cfg.num_prefix_tokens
                  if self.engine.cfg.modality == "vlm" else 0)
        return len(req.tokens) + prefix + req.max_new_tokens - 1

    def submit(self, req: Request) -> None:
        # A request's whole lifetime must fit the engine's cache capacity:
        # past capacity the GEAR streaming buffer would ring-wrap and corrupt
        # the slot silently, so reject at submit time.  A paged engine is
        # additionally bounded by its pool — reject requests that could
        # never be admitted even with every page free (transient pressure,
        # by contrast, just queues; see run_continuous).
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1")
        need = self._need_tokens(req)
        cap = self.engine._cap()
        if need > cap:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.tokens)} + budget "
                f"{req.max_new_tokens} needs {need} cache tokens but engine "
                f"capacity is {cap}")
        pool = self.engine.pool
        if pool is not None:
            pages = pages_needed(need, self.engine.ecfg.policy.buffer_size)
            most = min(pool.n_pages - 1, pool.n_chunks)
            if pages > most:
                raise ValueError(
                    f"request {req.rid}: needs {pages} pool pages but the "
                    f"engine can ever allocate at most {most} to one slot "
                    f"({pool.n_pages - 1} allocatable, {pool.n_chunks} "
                    "block-table entries)")
        self.submitted_rids.append(req.rid)
        self._submit_t[req.rid] = self._clock()
        o = self.obs
        if self.valve.shed(len(self.queue)):
            # load shedding: an immediate terminal result (delivered with
            # the next run) beats queueing behind work that cannot finish
            self._shed.append(Result(
                rid=req.rid, tokens=_EMPTY, prefill_s=0.0, decode_s=0.0,
                status=RequestStatus.REJECTED, attempts=0,
                error=f"shed at submit: queue at max_queue={self.valve.max_queue}"))
            if o is not None:
                o.on_shed(req.rid)
                o.result(str(RequestStatus.REJECTED))
            return
        self.queue.append(req)
        if o is not None:
            o.on_submit(req.rid)
            o.queue_depth(len(self.queue))

    def _drain_shed(self) -> list[Result]:
        out, self._shed = self._shed, []
        return out

    def audit(self, results: list[Result]) -> dict:
        """Post-run invariant report: every submitted rid terminated with
        exactly ONE result, plus the engine's pool/trie audit.  ``results``
        is everything collected from this scheduler's runs.  Never raises.
        """
        counts = Counter(r.rid for r in results)
        issues = [f"rid {rid}: {counts.get(rid, 0)} results (want 1)"
                  for rid in self.submitted_rids if counts.get(rid, 0) != 1]
        issues += [f"rid {rid}: result without a submit"
                   for rid in counts if rid not in set(self.submitted_rids)]
        eng_report = self.engine.audit()
        issues += eng_report["issues"]
        return {"ok": not issues, "issues": issues, "engine": eng_report}

    # ------------------------------------------------------------------
    # Wave mode
    def run(self) -> list[Result]:
        """Drain the queue in engine-batch-sized waves.

        Each wave shares ONE full-batch prefill program, so its prompts are
        left-padded to the wave's longest raw prompt.  Left-padding shifts
        chunk boundaries, so a request's numerics depend on its wave's
        composition — use :meth:`run_continuous` when per-request
        reproducibility or prefix-cache reuse matters.
        """
        results: list[Result] = self._drain_shed()
        B = self.engine.ecfg.batch
        eos = self.engine.ecfg.eos_id
        t_all = time.time()
        while self.queue:
            wave = [self.queue.popleft() for _ in range(min(B, len(self.queue)))]
            while len(wave) < B:                      # pad with a copy slot
                wave.append(Request(rid=-1, tokens=wave[0].tokens,
                                    max_new_tokens=wave[0].max_new_tokens))
            wave_pad = max(len(r.tokens) for r in wave)
            prompts = np.stack([_pad(r.tokens, wave_pad) for r in wave])
            budget = max(r.max_new_tokens for r in wave)
            toks, stats = self.engine.generate(
                {"tokens": jnp.asarray(prompts, jnp.int32)}, budget,
                active=np.array([r.rid >= 0 for r in wave]))
            toks = np.asarray(toks)
            for i, r in enumerate(wave):
                if r.rid < 0:
                    continue
                res = Result(
                    rid=r.rid,
                    tokens=_truncate_eos(toks[i, : r.max_new_tokens], eos),
                    prefill_s=stats["prefill_s"],
                    decode_s=stats["decode_s"])
                results.append(res)
                if self.obs is not None:
                    self.obs.result(str(res.status))
                    self.obs.tracer.finish(r.rid, str(res.status))
        self.last_stats = {"wall_s": time.time() - t_all,
                           "tokens": int(sum(len(r.tokens) for r in results)),
                           "statuses": dict(Counter(str(r.status)
                                                    for r in results))}
        return results

    # ------------------------------------------------------------------
    # Continuous mode
    def run_continuous(self) -> list[Result]:
        """Drain the queue with slot-level continuous batching.

        Greedy-deterministic at ``temperature == 0``: each request's tokens
        are bit-identical to a solo run regardless of what shares the batch.

        Every submitted request terminates with exactly one typed
        :class:`Result` (the chaos suite audits this): admission failures
        (:class:`~repro.serving.pagedpool.PoolExhausted`) retry at most
        ``retry.max_attempts`` times with backoff before a terminal
        ``REJECTED``; a NaN/Inf-poisoned prefill
        (:class:`~repro.core.cache.NumericFault`) fails only that request
        — the engine already rolled back its reservation, so co-batched
        slots continue bit-identically; engine-step faults retry bounded,
        then fail the affected slots; deadlines surface ``TIMEOUT`` with
        whatever tokens existed at the cutoff.
        """
        eng = self.engine
        if eng.cfg.modality == "audio":
            raise NotImplementedError("continuous batching drives text tokens")
        B = eng.ecfg.batch
        eos = eng.ecfg.eos_id
        key = jax.random.PRNGKey(0)

        results: list[Result] = self._drain_shed()
        # the view owns the live cache tree and answers admission for both
        # layouts; dense admission is slot-count-limited (can_admit always
        # True), paged admission is pool-bytes-limited
        view = eng.new_view()
        # engine prefix-cache counters are lifetime-cumulative; snapshot so
        # last_stats reports THIS run's rates, like every other field in it
        # (a paged engine's new_view re-keys the trie, so snapshot AFTER)
        pstats0 = (eng.prefix_cache.snapshot()
                   if eng.prefix_cache is not None else None)
        obs = self.obs
        pos = np.zeros(B, np.int32)        # per-slot absolute decode position
        budget = np.zeros(B, np.int32)     # per-slot remaining-token budget
        done = np.ones(B, bool)            # per-slot idle flag
        fresh = np.ones(B, bool)           # per-slot cache row is empty-state
        reqs: list[Request | None] = [None] * B
        toks_buf: list[list[int]] = [[] for _ in range(B)]
        cur = np.zeros(B, np.int32)        # last sampled token per slot
        prefill_s = np.zeros(B)
        decode_s = np.zeros(B)
        steps = 0
        t_decode_total = 0.0
        t_all = time.time()
        attempts: dict[int, int] = {}   # admission/fault retries per rid
        degraded: set[int] = set()      # completed-but-impaired rids
        not_before = 0.0                # admission backoff gate (sched clock)
        dec_faults = 0                  # consecutive failed decode steps

        def expired(r: Request) -> bool:
            return (r.deadline_s is not None
                    and self._clock() - self._submit_t.get(r.rid, 0.0)
                    > r.deadline_s)

        def terminal(r: Request, status: RequestStatus, error: str,
                     tokens=_EMPTY) -> None:
            """Emit a non-completion result for a request not in a slot.
            ``attempts`` in the result counts admission attempts consumed
            (already tallied in the dict by the failure handlers)."""
            results.append(Result(
                rid=r.rid, tokens=np.asarray(tokens, np.int32),
                prefill_s=0.0, decode_s=0.0, status=status,
                attempts=attempts.get(r.rid, 0), error=error))
            if obs is not None:
                obs.result(str(status))
                if error:
                    obs.tracer.event(r.rid, "terminal", error=error)
                obs.tracer.finish(r.rid, str(status))

        def reap_expired_queue() -> None:
            """Queued requests past their deadline: empty TIMEOUT results."""
            n = len(self.queue)
            for _ in range(n):
                r = self.queue.popleft()
                if expired(r):
                    terminal(r, RequestStatus.TIMEOUT,
                             f"deadline {r.deadline_s}s elapsed while queued")
                else:
                    self.queue.append(r)

        def finish(s: int, status: RequestStatus | None = None,
                   error: str = "") -> None:
            r = reqs[s]
            if status is None:
                status = (RequestStatus.DEGRADED
                          if attempts.get(r.rid, 0) or r.rid in degraded
                          else RequestStatus.OK)
            results.append(Result(
                rid=r.rid,
                tokens=_truncate_eos(np.asarray(toks_buf[s], np.int32), eos),
                prefill_s=float(prefill_s[s]),
                decode_s=float(decode_s[s]),
                status=status, attempts=attempts.get(r.rid, 0) + 1,
                error=error))
            if obs is not None:
                obs.result(str(status))
                obs.tracer.add_span(r.rid, "decode", float(decode_s[s]),
                                    steps=len(toks_buf[s]))
                if error:
                    obs.tracer.event(r.rid, "terminal", error=error)
                obs.tracer.finish(r.rid, str(status))
            reqs[s] = None
            done[s] = True
            cur[s] = 0

        def admit_failed(r: Request, exc: Exception,
                         status: RequestStatus) -> bool:
            """Bounded-retry bookkeeping for a failed admission.  Returns
            True when the request was terminally resolved (do not requeue),
            False when it went back to the queue head to retry later."""
            nonlocal not_before
            attempts[r.rid] = attempts.get(r.rid, 0) + 1
            if obs is not None:
                obs.retry("admission")
                obs.tracer.event(r.rid, "retry", kind="admission",
                                 attempt=attempts[r.rid], error=str(exc))
            if attempts[r.rid] >= self.retry.max_attempts:
                terminal(r, status,
                         f"admission failed {attempts[r.rid]}x: {exc}")
                return True
            self.queue.appendleft(r)
            not_before = self._clock() + self.retry.backoff(attempts[r.rid])
            return False

        def splice(s: int) -> bool:
            """Admit the queue head into idle slot ``s``.  True when the
            slot's state may have changed (spliced, or the head resolved
            terminally — the admission loop may try the next request);
            False when the head was requeued for a later retry."""
            r = self.queue.popleft()
            if expired(r):
                terminal(r, RequestStatus.TIMEOUT,
                         f"deadline {r.deadline_s}s elapsed while queued")
                return True
            prompt = np.asarray(r.tokens, np.int32)[None]   # raw, unpadded
            if obs is not None:
                tr = obs.tracer.active.get(r.rid)
                if tr is not None:
                    obs.observe_queue_wait(
                        max(self._clock() - tr.t_submit, 0.0))
                obs.tracer.end(r.rid)   # close "queued"
                obs.tracer.attempt(r.rid)
                obs.tracer.begin(r.rid, "prefill",
                                 attempt=attempts.get(r.rid, 0) + 1, slot=s)
                obs.tracer.bind(r.rid)
            t0 = time.time()
            try:
                if self._faults is not None:
                    self._faults.check_step("prefill")
                logits = view.prefill_slot(
                    {"tokens": jnp.asarray(prompt, jnp.int32)}, s,
                    admit=self.prefix_admission == "all",
                    reserve_tokens=self._need_tokens(r))
            except PoolExhausted as e:
                # can_admit raced another consumer of the pool (e.g. trie
                # admission of a concurrent splice) or the fault injector
                # forced exhaustion: bounded retry, then REJECTED — pages
                # normally come back when a running slot finishes, but an
                # unbounded requeue livelocks under sustained pressure
                return admit_failed(r, e, RequestStatus.REJECTED)
            except NumericFault as e:
                # quarantine: the engine rolled its reservation back and
                # never touched the shared tree; only THIS request fails
                attempts[r.rid] = attempts.get(r.rid, 0) + 1
                terminal(r, RequestStatus.FAILED, f"numeric quarantine: {e}")
                return True
            except InjectedFault as e:
                # transient engine-step fault raised before any device work:
                # bounded retry (it completes DEGRADED), then FAILED
                degraded.add(r.rid)
                return admit_failed(r, e, RequestStatus.FAILED)
            finally:
                if obs is not None:
                    obs.tracer.unbind()
                    obs.tracer.end(r.rid)   # close "prefill"
            first = int(np.asarray(
                sample(logits[:, -1], key, eng.ecfg.temperature, eng.ecfg.top_k))[0])
            prefill_s[s] = time.time() - t0
            if obs is not None:
                obs.observe_prefill(float(prefill_s[s]))
            fresh[s] = False
            reqs[s] = r
            toks_buf[s] = [first]
            cur[s] = first
            pos[s] = eng._prompt_len({"tokens": prompt})
            budget[s] = r.max_new_tokens
            decode_s[s] = 0.0
            done[s] = False
            if r.max_new_tokens <= 1 or (eos >= 0 and first == eos):
                finish(s)
            return True

        def head_ready() -> bool:
            """May the queue head attempt admission right now?  Gated on
            the retry backoff window and the view's capacity answer."""
            return (self._clock() >= not_before
                    and view.can_admit(self._need_tokens(self.queue[0])))

        while self.queue or not bool(done.all()):
            if self._faults is not None:
                self._faults.tick(eng)
            reap_expired_queue()
            for s in range(B):
                while done[s] and self.queue and head_ready():
                    if not splice(s):
                        break
                if done[s] and not fresh[s]:
                    # queue drained (or head inadmissible): clear the slot so
                    # it idles on an empty cache row instead of decoding
                    # stale request state — and, paged, releases its pages
                    view.reset_slot(s)
                    fresh[s] = True
                    pos[s] = 0
                    cur[s] = 0
            if bool(done.all()):
                if not self.queue:
                    break
                now = self._clock()
                if now < not_before:
                    # idle engine inside a backoff window: sleep it off
                    self._sleep(not_before - now)
                    continue
                # every slot is idle yet the head request was not admitted:
                # the pool's free pages are pinned by the prefix trie.
                # Reclaim (LRU-evict trie entries back into allocatable
                # pages) and retry; when reclaim frees nothing (empty or
                # fully-pinned trie), bounded attempts surface a terminal
                # REJECTED instead of spinning forever.
                r = self.queue[0]
                need = self._need_tokens(r)
                if view.reclaim(need) or view.can_admit(need):
                    continue
                self.queue.popleft()
                if not admit_failed(
                        r, PoolExhausted(
                            f"need {need} tokens, idle engine, reclaim freed "
                            "nothing"),
                        RequestStatus.REJECTED):
                    # requeued for another attempt after its backoff
                    self._sleep(max(not_before - self._clock(), 0.0))
                continue
            if self._faults is not None:
                try:
                    self._faults.check_step("decode")
                except InjectedFault as e:
                    # fault raised BEFORE the jitted step dispatches, so the
                    # donated cache tree is untouched — retry is safe
                    dec_faults += 1
                    active = list(np.nonzero(~done)[0])
                    if obs is not None:
                        obs.retry("decode")
                        for s in active:
                            obs.tracer.event(reqs[s].rid, "retry",
                                             kind="decode",
                                             attempt=dec_faults)
                    if dec_faults >= self.retry.max_attempts:
                        for s in active:
                            finish(s, status=RequestStatus.FAILED,
                                   error=f"decode failed {dec_faults}x: {e}")
                        dec_faults = 0
                    else:
                        degraded.update(reqs[s].rid for s in active)
                        self._sleep(self.retry.backoff(dec_faults))
                    continue
                dec_faults = 0
            t0 = time.time()
            tb = {"tokens": jnp.asarray(cur[:, None])}
            logits = view.decode(tb, pos)
            key = jax.random.fold_in(key, steps)
            nxt = np.asarray(sample(logits[:, -1], key,
                                    eng.ecfg.temperature, eng.ecfg.top_k))
            step_t = time.time() - t0
            t_decode_total += step_t
            steps += 1
            pos += 1  # idle slots advance harmlessly; a splice rewrites pos[s]
            active_slots = np.nonzero(~done)[0]
            if obs is not None:
                obs.decode_step(step_t, len(active_slots))
                obs.queue_depth(len(self.queue))
            for s in active_slots:
                decode_s[s] += step_t
                if obs is not None:
                    obs.tracer.step(reqs[s].rid)
                tok = int(nxt[s])
                toks_buf[s].append(tok)
                cur[s] = tok
                if (eos >= 0 and tok == eos) or len(toks_buf[s]) >= budget[s]:
                    finish(s)
                elif expired(reqs[s]):
                    finish(s, status=RequestStatus.TIMEOUT,
                           error=f"deadline {reqs[s].deadline_s}s elapsed "
                                 "mid-decode")

        self.last_stats = {
            "wall_s": time.time() - t_all,
            "decode_s": t_decode_total,
            "decode_steps": steps,
            "tokens": int(sum(len(r.tokens) for r in results)),
            "attend_path": eng.attend_path,
            "layout": str(eng.ecfg.layout),
            "statuses": dict(Counter(str(r.status) for r in results)),
        }
        if eng.pool is not None:
            # typed snapshot; PoolSnapshot indexes like the old dict entry
            self.last_stats["pool"] = eng.pool.snapshot()
        if pstats0 is not None:
            pstats = eng.prefix_cache.snapshot()
            hit = pstats.hit_chunks - pstats0.hit_chunks
            look = pstats.lookup_chunks - pstats0.lookup_chunks
            self.last_stats["prefix_hit_rate"] = hit / max(look, 1)
            self.last_stats["prefill_toks_saved"] = (
                pstats.prefill_toks_saved - pstats0.prefill_toks_saved)
            self.last_stats["prefix_evictions"] = (
                pstats.evictions - pstats0.evictions)
            self.last_stats["prefix_expiries"] = (
                pstats.expiries - pstats0.expiries)
            self.last_stats["prefix_version_evictions"] = (
                pstats.version_evictions - pstats0.version_evictions)
            self.last_stats["prefix"] = pstats
        if obs is not None:
            # fold lifetime component counters into the registry (delta
            # semantics with reset detection — a paged new_view rebuilds
            # the pool/trie and zeroes their cumulative stats)
            if eng.pool is not None:
                obs.sync_pool(self.last_stats["pool"])
            if eng.prefix_cache is not None:
                obs.sync_prefix(eng.prefix_cache.snapshot())
            obs.queue_depth(len(self.queue))
        return results


def _truncate_eos(tokens: np.ndarray, eos_id: int) -> np.ndarray:
    """Trim generated ids at the request's own first EOS (kept inclusive)."""
    if eos_id < 0:
        return tokens
    hits = np.nonzero(tokens == eos_id)[0]
    return tokens[: hits[0] + 1] if hits.size else tokens


def _pad(tokens: np.ndarray, length: int) -> np.ndarray:
    """Left-pad (or left-truncate) to ``length`` — wave mode's per-wave
    prompt alignment; continuous mode sends raw prompts instead."""
    if len(tokens) >= length:
        return tokens[-length:]
    return np.pad(tokens, (length - len(tokens), 0))
