"""GEAR core: KV-cache compression (quant backbone + low-rank + sparse)."""

from repro.core.policy import CompressionPolicy, FP16, GEAR_DEFAULT, named_policy
from repro.core.gear import CompressedMatrix, compress_matrix, decompress_matrix, approx_error
from repro.core.cache import (
    CacheConfig,
    GEARLayerCache,
    FP16LayerCache,
    WindowLayerCache,
    init_layer_cache,
    prefill_layer_cache,
    streaming_prefill_layer_cache,
    append_token,
    attend,
    dense_kv,
    splice_slot,
    reset_slot,
    prefill_into_slot,
    fresh_batch1_cache,
)
from repro.core.metrics import kv_size_breakdown, kv_size_fraction

__all__ = [
    "CompressionPolicy", "FP16", "GEAR_DEFAULT", "named_policy",
    "CompressedMatrix", "compress_matrix", "decompress_matrix", "approx_error",
    "CacheConfig", "GEARLayerCache", "FP16LayerCache", "WindowLayerCache",
    "init_layer_cache", "prefill_layer_cache", "streaming_prefill_layer_cache",
    "append_token", "attend", "dense_kv",
    "splice_slot", "reset_slot", "prefill_into_slot", "fresh_batch1_cache",
    "kv_size_breakdown", "kv_size_fraction",
]
