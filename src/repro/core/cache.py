"""Static-shape GEAR-compressed KV cache with streaming buffer.

This is the serving-engine representation of the paper's Algorithm 1 under
XLA's static-shape constraint:

* The cache is divided into **chunks** of ``n_b`` tokens (= the streaming
  buffer size).  Newly decoded tokens land in an FP16 ring buffer; once the
  buffer holds ``n_b`` tokens it is compressed as one chunk (quant backbone +
  per-chunk low-rank factors + per-chunk outliers) and written into the
  packed arrays at its chunk index — a ``lax.cond`` keeps the whole decode
  step a single XLA program.
* Prefill compresses ``n // n_b`` chunks in one batched call (leading-dim
  batching of :func:`repro.core.gear.compress_matrix`), leftover tokens go to
  the buffer.
* Attention never materializes the FP16 cache: scores are computed from the
  packed codes via the identity ``q·K̂ᵀ = (q⊙scale)·codesᵀ + q·zero`` (for
  per-channel K quant), the low-rank path is evaluated factored
  (``(q·B_c)·A_cᵀ``, the paper's separate-path trick), and outliers are
  applied per-chunk.  The Pallas kernel (:mod:`repro.kernels.gear_decode`)
  fuses the same math; this module is the jnp reference/portable path.

Shapes (H = kv heads, S = capacity, C = S/n_b chunks, r = policy.rank,
per = 32 // bits packed lanes):

  k_packed  int32 [B, H, S, Dh/per]      v_packed  int32 [B, H, S, Dh/per]
  k_scale   bf16  [B, H, Ck, Dh]         v_scale   bf16  [B, H, S, Gv]
  k_zero            (same as k_scale)    v_zero            (same as v_scale)
  k_a       bf16  [B, H, S, r]           v_a       bf16  [B, H, S, r]
  k_b       bf16  [B, H, C, Dh, r]       v_b       bf16  [B, H, C, Dh, r]
  k_sp_val  bf16  [B, H, C, Dh, 2ks]     v_sp_val  bf16  [B, H, S, 2kv]
  k_sp_idx  int32   (same)               v_sp_idx  int32   (same)
  buf_k/buf_v bf16 [B, H, n_b, Dh]       length    int32 [B]

(for the per-token-group baseline backbone K uses the V layout.)

**Per-slot state.**  ``length`` (and the window cache's ``pos``) carry a
leading batch dim: every batch row is an independent *slot* that may hold a
different request at a different phase of its life.  All decode-time writes
(:func:`append_token`) address each slot at its own offset, and all attend
masks are per-slot — this is what makes slot-level continuous batching
(:func:`prefill_into_slot` / :func:`reset_slot` / :func:`splice_slot`) a pure
batch-dim operation.  The slot-splice protocol is specified in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import gear as gear_lib
from repro.core import lowrank as lr_lib
from repro.core import outlier as ol_lib
from repro.core import packing
from repro.core.policy import CompressionPolicy

__all__ = [
    "CacheConfig",
    "GEARLayerCache",
    "FP16LayerCache",
    "WindowLayerCache",
    "init_layer_cache",
    "prefill_layer_cache",
    "streaming_supported",
    "streaming_prefill_pipeline",
    "streaming_prefill_layer_cache",
    "append_token",
    "attend",
    "dense_kv",
    "extract_prefix_chunks",
    "splice_prefix_chunks",
    "NumericFault",
    "tree_finite",
    "splice_slot",
    "reset_slot",
    "prefill_into_slot",
    "fresh_batch1_cache",
    "PagedGEARLayerCache",
    "paged_supported",
    "page_field_shapes",
    "page_nbytes",
    "init_paged_layer_cache",
    "paged_to_dense",
    "gather_pool_chunks",
    "scatter_pool_chunks",
    "zero_pool_pages",
    "append_token_paged",
    "attend_paged",
]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Static geometry of one attention layer's cache."""

    batch: int
    kv_heads: int
    head_dim: int
    capacity: int            # max tokens (multiple of chunk)
    policy: CompressionPolicy
    kind: str = "gear"       # "gear" | "fp16" | "window"
    window: int = 0          # for kind == "window"

    def __post_init__(self):
        if self.kind == "gear" and self.capacity % self.chunk:
            raise ValueError(f"capacity {self.capacity} not a multiple of chunk {self.chunk}")

    @property
    def chunk(self) -> int:
        return self.policy.buffer_size

    @property
    def n_chunks(self) -> int:
        return self.capacity // self.chunk

    def k_scheme(self):
        return self.policy.scheme_for("k")

    def v_scheme(self):
        return self.policy.scheme_for("v")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "k_packed", "k_scale", "k_zero", "v_packed", "v_scale", "v_zero",
        "k_a", "k_b", "v_a", "v_b",
        "k_sp_val", "k_sp_idx", "v_sp_val", "v_sp_idx",
        "buf_k", "buf_v", "length",
    ],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class GEARLayerCache:
    k_packed: Any; k_scale: Any; k_zero: Any
    v_packed: Any; v_scale: Any; v_zero: Any
    k_a: Any; k_b: Any; v_a: Any; v_b: Any
    k_sp_val: Any; k_sp_idx: Any; v_sp_val: Any; v_sp_idx: Any
    buf_k: Any; buf_v: Any
    length: Any


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "length"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class FP16LayerCache:
    k: Any
    v: Any
    length: Any


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "pos", "length"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class WindowLayerCache:
    """Ring buffer of the most recent ``window`` tokens (fp16)."""
    k: Any
    v: Any
    pos: Any      # int32 [B, window] absolute position held per ring slot (-1 empty)
    length: Any


# ---------------------------------------------------------------------------
# Shape helpers


def _k_stat_rows(cfg: CacheConfig) -> tuple[int, int]:
    scheme, group = cfg.k_scheme()
    if scheme == "per_channel":
        g = cfg.chunk if group is None else group
        return cfg.n_chunks * (cfg.chunk // g), cfg.head_dim
    g = cfg.head_dim if group is None else group
    return cfg.capacity, cfg.head_dim // g


def _v_stat_rows(cfg: CacheConfig) -> tuple[int, int]:
    scheme, group = cfg.v_scheme()
    g = cfg.head_dim if group is None else group
    return cfg.capacity, cfg.head_dim // g


def _sparse_caps(cfg: CacheConfig) -> tuple[int, int]:
    from repro.core.outlier import outlier_count
    ks = outlier_count(cfg.chunk, cfg.policy.sparsity)       # K: along tokens in chunk
    kv = outlier_count(cfg.head_dim, cfg.policy.sparsity)    # V: along channels
    return ks, kv


def init_layer_cache(cfg: CacheConfig, dtype=jnp.bfloat16):
    B, H, Dh, S = cfg.batch, cfg.kv_heads, cfg.head_dim, cfg.capacity
    if cfg.kind == "fp16":
        return FP16LayerCache(
            k=jnp.zeros((B, H, S, Dh), dtype),
            v=jnp.zeros((B, H, S, Dh), dtype),
            length=jnp.zeros((B,), jnp.int32),
        )
    if cfg.kind == "window":
        W = cfg.window
        return WindowLayerCache(
            k=jnp.zeros((B, H, W, Dh), dtype),
            v=jnp.zeros((B, H, W, Dh), dtype),
            pos=jnp.full((B, W), -1, jnp.int32),
            length=jnp.zeros((B,), jnp.int32),
        )
    pol = cfg.policy
    per = 32 // pol.bits
    C = cfg.n_chunks
    r = pol.rank
    ks, kvo = _sparse_caps(cfg)
    krows, kcols = _k_stat_rows(cfg)
    vrows, vcols = _v_stat_rows(cfg)
    use_lr, use_sp = pol.use_lowrank, pol.use_sparse
    z = lambda *shape: jnp.zeros(shape, dtype)
    zi = lambda *shape: jnp.zeros(shape, jnp.int32)
    k_is_channel = cfg.k_scheme()[0] == "per_channel"
    return GEARLayerCache(
        k_packed=zi(B, H, S, Dh // per),
        k_scale=z(B, H, krows, kcols),
        k_zero=z(B, H, krows, kcols),
        v_packed=zi(B, H, S, Dh // per),
        v_scale=z(B, H, vrows, vcols),
        v_zero=z(B, H, vrows, vcols),
        k_a=z(B, H, S, r) if use_lr else None,
        k_b=z(B, H, C, Dh, r) if use_lr else None,
        v_a=z(B, H, S, r) if use_lr else None,
        v_b=z(B, H, C, Dh, r) if use_lr else None,
        k_sp_val=(z(B, H, C, Dh, 2 * ks) if k_is_channel else z(B, H, S, 2 * kvo)) if use_sp else None,
        k_sp_idx=(zi(B, H, C, Dh, 2 * ks) if k_is_channel else zi(B, H, S, 2 * kvo)) if use_sp else None,
        v_sp_val=z(B, H, S, 2 * kvo) if use_sp else None,
        v_sp_idx=zi(B, H, S, 2 * kvo) if use_sp else None,
        buf_k=z(B, H, pol.buffer_size, Dh),
        buf_v=z(B, H, pol.buffer_size, Dh),
        length=jnp.zeros((B,), jnp.int32),
    )


def _slot_rows_update(dst: jnp.ndarray, vals: jnp.ndarray, start: jnp.ndarray,
                      need: jnp.ndarray | None = None) -> jnp.ndarray:
    """Write ``vals`` [B, H, r, ...] into ``dst`` [B, H, R, ...] at per-slot
    row offset ``start`` [B] along axis 2.

    Slots with ``need[b]`` False (and slots whose rows would run past the end
    of ``dst``) are redirected out of bounds, which the scatter drops — the
    mechanism that lets one batched write serve slots at different phases.
    """
    B, r, R = dst.shape[0], vals.shape[2], dst.shape[2]
    rows = start.astype(jnp.int32)[:, None] + jnp.arange(r, dtype=jnp.int32)[None, :]
    if need is not None:
        rows = jnp.where(need[:, None], rows, R)
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    # advanced indices at axes (0, 2) move to the front: update is [B, r, H, ...]
    return dst.at[bidx, :, rows].set(
        jnp.moveaxis(vals, 2, 1).astype(dst.dtype), mode="drop")


# ---------------------------------------------------------------------------
# Compression of chunk batches


def _compress_chunks(cfg: CacheConfig, k: jnp.ndarray, v: jnp.ndarray,
                     rank: int, key: jax.Array, fused: str = "off"):
    """Compress ``k``/``v`` [B, H, C', nb, Dh] -> dict of per-chunk arrays.

    C' is the number of chunks being compressed in this event (prefill: many,
    decode: 1).  Low-rank factors are zero-padded to ``policy.rank`` columns.

    ``fused`` selects the quantize/pack/stats/outlier implementation:
    "off" — :func:`repro.core.gear.compress_matrix` (plain XLA);
    "auto" — the fused ``gear_compress`` Pallas kernel on TPU, its bit-exact
    jnp oracle elsewhere; "interpret" — force the kernel in interpret mode
    (CI kernel lane).  The power-iteration low-rank step always runs in XLA,
    on the kernel-emitted quantization residual of this event's chunks only.
    """
    if fused != "off":
        return _compress_chunks_fused(cfg, k, v, rank, key, fused)
    pol = cfg.policy
    out = {}
    for name, x, kind in (("k", k, "k"), ("v", v, "v")):
        cm = gear_lib.compress_matrix(x, pol, kind, rank=rank, key=key)
        out[f"{name}_packed"] = cm.qt.packed
        out[f"{name}_scale"] = cm.qt.scale.astype(jnp.bfloat16)
        out[f"{name}_zero"] = cm.qt.zero.astype(jnp.bfloat16)
        if pol.use_lowrank:
            a, b = cm.a, cm.b
            pad = pol.rank - rank
            if pad:
                a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
                b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
            out[f"{name}_a"], out[f"{name}_b"] = a, b
        if pol.use_sparse:
            out[f"{name}_sp_val"] = cm.sparse.values.astype(jnp.bfloat16)
            out[f"{name}_sp_idx"] = cm.sparse.indices.astype(jnp.int32)
    return out


def _compress_chunks_fused(cfg: CacheConfig, k: jnp.ndarray, v: jnp.ndarray,
                           rank: int, key: jax.Array, fused: str):
    """Fused-kernel twin of :func:`_compress_chunks` (same output layout)."""
    from repro.kernels import ops as kernel_ops  # lazy: kernels import us

    pol = cfg.policy
    force = fused == "interpret"
    out = {}
    for name, x, kind in (("k", k, "k"), ("v", v, "v")):
        scheme, group = pol.scheme_for(kind)
        B, H, C, nb, Dh = x.shape
        vec_len = nb if scheme == "per_channel" else Dh
        n_out = ol_lib.outlier_count(vec_len, pol.sparsity) if pol.use_sparse else 0
        packed, scale, zero, spv, spi, resid = kernel_ops.gear_compress_chunks(
            x.reshape(B * H * C, nb, Dh), bits=pol.bits, scheme=scheme,
            group=group, n_out=n_out, stat_dtype=pol.stat_dtype,
            force_kernel=force, interpret=force)
        lead = (B, H, C)
        out[f"{name}_packed"] = packed.reshape(lead + packed.shape[1:])
        out[f"{name}_scale"] = scale.reshape(lead + scale.shape[1:]).astype(jnp.bfloat16)
        out[f"{name}_zero"] = zero.reshape(lead + zero.shape[1:]).astype(jnp.bfloat16)
        if pol.use_lowrank:
            a, b = lr_lib.power_iteration(resid.reshape(lead + (nb, Dh)), rank,
                                          pol.power_iters, key)
            a = a.astype(jnp.bfloat16)
            b = b.astype(jnp.bfloat16)
            pad = pol.rank - rank
            if pad:
                a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
                b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
            out[f"{name}_a"], out[f"{name}_b"] = a, b
        if pol.use_sparse:
            out[f"{name}_sp_val"] = spv.reshape(lead + spv.shape[1:]).astype(jnp.bfloat16)
            out[f"{name}_sp_idx"] = spi.reshape(lead + spi.shape[1:]).astype(jnp.int32)
    return out


def _flatten_stat(cfg: CacheConfig, stat: jnp.ndarray, kind: str) -> jnp.ndarray:
    """[B,H,C',rows_per_chunk,cols] -> [B,H,C'*rows_per_chunk,cols]."""
    B, H = stat.shape[0], stat.shape[1]
    return stat.reshape(B, H, -1, stat.shape[-1])


def _store_prefill_chunks(cfg: CacheConfig, upd: dict, comp: dict,
                          n_full: int, start_chunk: int = 0) -> dict:
    """Write one compression event's ``C' = n_full / n_b`` chunks into the
    cache arrays of ``upd`` starting at chunk ``start_chunk`` (token
    ``start_chunk * n_b``).  Shared by monolithic prefill (one batched
    event, offset 0), streaming prefill (per-chunk events stacked by the
    compression scan — same layout either way), and suffix prefill over a
    cached prefix (``start_chunk`` = chunks already spliced from the prefix
    cache)."""
    pol = cfg.policy
    B, H = upd["k_packed"].shape[:2]
    t0 = start_chunk * cfg.chunk
    z4 = (0, 0, t0, 0)
    upd["k_packed"] = jax.lax.dynamic_update_slice(
        upd["k_packed"], comp["k_packed"].reshape(B, H, n_full, -1), z4)
    upd["v_packed"] = jax.lax.dynamic_update_slice(
        upd["v_packed"], comp["v_packed"].reshape(B, H, n_full, -1), z4)
    for kv in ("k", "v"):
        stat_s = _flatten_stat(cfg, comp[f"{kv}_scale"], kv)
        stat_z = _flatten_stat(cfg, comp[f"{kv}_zero"], kv)
        rpc = stat_s.shape[2] // max(n_full // cfg.chunk, 1)
        zs = (0, 0, start_chunk * rpc, 0)
        upd[f"{kv}_scale"] = jax.lax.dynamic_update_slice(upd[f"{kv}_scale"], stat_s, zs)
        upd[f"{kv}_zero"] = jax.lax.dynamic_update_slice(upd[f"{kv}_zero"], stat_z, zs)
        if pol.use_lowrank:
            a = comp[f"{kv}_a"].reshape(B, H, n_full, pol.rank)
            upd[f"{kv}_a"] = jax.lax.dynamic_update_slice(upd[f"{kv}_a"], a, z4)
            upd[f"{kv}_b"] = jax.lax.dynamic_update_slice(
                upd[f"{kv}_b"], comp[f"{kv}_b"], (0, 0, start_chunk, 0, 0))
        if pol.use_sparse:
            sv, si = comp[f"{kv}_sp_val"], comp[f"{kv}_sp_idx"]
            if kv == "v" or cfg.k_scheme()[0] != "per_channel":
                sv = sv.reshape(B, H, n_full, sv.shape[-1])
                si = si.reshape(B, H, n_full, si.shape[-1])
                upd[f"{kv}_sp_val"] = jax.lax.dynamic_update_slice(upd[f"{kv}_sp_val"], sv, z4)
                upd[f"{kv}_sp_idx"] = jax.lax.dynamic_update_slice(upd[f"{kv}_sp_idx"], si, z4)
            else:
                upd[f"{kv}_sp_val"] = jax.lax.dynamic_update_slice(
                    upd[f"{kv}_sp_val"], sv, (0, 0, start_chunk, 0, 0))
                upd[f"{kv}_sp_idx"] = jax.lax.dynamic_update_slice(
                    upd[f"{kv}_sp_idx"], si, (0, 0, start_chunk, 0, 0))
    return upd


def prefill_layer_cache(cfg: CacheConfig, cache, k: jnp.ndarray, v: jnp.ndarray,
                        key: jax.Array | None = None):
    """Fill a fresh layer cache from prefill K/V [B, H, n, Dh]."""
    n = k.shape[2]
    B = k.shape[0]
    full_len = jnp.full((B,), n, jnp.int32)
    if cfg.kind == "fp16":
        return FP16LayerCache(
            k=jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)),
            length=full_len,
        )
    if cfg.kind == "window":
        W = cfg.window
        # keep the last W tokens
        take = min(n, W)
        ks = k[:, :, n - take:, :]
        vs = v[:, :, n - take:, :]
        pos_vals = jnp.arange(n - take, n, dtype=jnp.int32)
        slots = pos_vals % W
        knew = cache.k.at[:, :, slots, :].set(ks.astype(cache.k.dtype))
        vnew = cache.v.at[:, :, slots, :].set(vs.astype(cache.v.dtype))
        pos = cache.pos.at[:, slots].set(pos_vals[None, :])
        return WindowLayerCache(k=knew, v=vnew, pos=pos, length=full_len)

    if key is None:
        key = jax.random.PRNGKey(0)
    pol = cfg.policy
    nb = cfg.chunk
    n_full = (n // nb) * nb
    C_new = n_full // nb
    upd = {f.name: getattr(cache, f.name) for f in dataclasses.fields(GEARLayerCache)}
    if C_new > 0:
        B, H, _, Dh = k.shape
        # f32 compression inputs: numerically identical for bf16 K/V (exact
        # widening; every internal step is f32 already) but avoids lax.top_k
        # on bf16, which hits a ~20x slower sort path on CPU
        kc = k[:, :, :n_full, :].reshape(B, H, C_new, nb, Dh).astype(jnp.float32)
        vc = v[:, :, :n_full, :].reshape(B, H, C_new, nb, Dh).astype(jnp.float32)
        comp = _compress_chunks(cfg, kc, vc, pol.rank, key)
        upd = _store_prefill_chunks(cfg, upd, comp, n_full)
    rem = n - n_full
    if rem:
        upd["buf_k"] = jax.lax.dynamic_update_slice(
            upd["buf_k"], k[:, :, n_full:, :].astype(upd["buf_k"].dtype), (0, 0, 0, 0))
        upd["buf_v"] = jax.lax.dynamic_update_slice(
            upd["buf_v"], v[:, :, n_full:, :].astype(upd["buf_v"].dtype), (0, 0, 0, 0))
    upd["length"] = full_len
    return GEARLayerCache(**upd)


def _attend_segments(n_chunks: int, segments: int = 4) -> list[tuple[int, int]]:
    """Equal [lo, hi) chunk segments for the prefix-view attend scans."""
    segments = min(segments, n_chunks)
    bounds = [round(n_chunks * j / segments) for j in range(segments + 1)]
    return [(lo, hi) for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]


def chunk_prefix_view(cfg: CacheConfig, cache, n_chunks: int):
    """Static view of the first ``n_chunks`` chunks of a GEAR cache.

    The streaming attend scan runs in segments, each against the shortest
    chunk prefix covering its queries — recovering most of the causal
    triangle the monolithic score matrix pays in full.  Scores beyond each
    query's own ``n_comp`` mask are exact zeros after the softmax either
    way, so segmenting only changes float accumulation width, never the
    math.  Buffer/length leaves pass through untouched.
    """
    if n_chunks >= cfg.n_chunks:
        return cache
    S_pre = n_chunks * cfg.chunk
    pol = cfg.policy
    scheme, group = cfg.k_scheme()
    if scheme == "per_channel":
        g = cfg.chunk if group is None else group
        k_rows = n_chunks * (cfg.chunk // g)
    else:
        k_rows = S_pre
    d = dict(
        k_packed=cache.k_packed[:, :, :S_pre],
        v_packed=cache.v_packed[:, :, :S_pre],
        k_scale=cache.k_scale[:, :, :k_rows],
        k_zero=cache.k_zero[:, :, :k_rows],
        v_scale=cache.v_scale[:, :, :S_pre],
        v_zero=cache.v_zero[:, :, :S_pre],
    )
    if pol.use_lowrank:
        d.update(k_a=cache.k_a[:, :, :S_pre], v_a=cache.v_a[:, :, :S_pre],
                 k_b=cache.k_b[:, :, :n_chunks], v_b=cache.v_b[:, :, :n_chunks])
    if pol.use_sparse:
        per_channel = scheme == "per_channel"
        d.update(
            k_sp_val=cache.k_sp_val[:, :, :n_chunks if per_channel else S_pre],
            k_sp_idx=cache.k_sp_idx[:, :, :n_chunks if per_channel else S_pre],
            v_sp_val=cache.v_sp_val[:, :, :S_pre],
            v_sp_idx=cache.v_sp_idx[:, :, :S_pre],
        )
    return dataclasses.replace(cache, **d)


def _assemble_scanned_chunks(cfg: CacheConfig, upd: dict, comp_s: dict,
                             n_full: int, start_chunk: int = 0) -> dict:
    """Stack a compression scan's per-chunk outputs (leaves [C', B, H, 1,
    ...]) into the batched-event layout and store them from chunk
    ``start_chunk`` (token 0 for a cold prefill)."""
    B, H = upd["k_packed"].shape[:2]

    def stack(t):
        C = t.shape[0]
        return jnp.moveaxis(t, 0, 2).reshape((B, H, C) + t.shape[4:])

    return _store_prefill_chunks(cfg, upd, {kk: stack(t) for kk, t in comp_s.items()},
                                 n_full, start_chunk)


def streaming_supported(cfg: CacheConfig) -> bool:
    """True when this layer cache can take the streaming prefill pipeline.

    The history scorer (``gear_decode`` / its oracles) streams one K-stat
    row per chunk, so — exactly like the fused decode path
    (:func:`repro.kernels.ops.fused_supported`) — it needs a GEAR cache
    with per-channel K quantization at chunk granularity.  Static; callers
    fall back to monolithic prefill when False.
    """
    if cfg.kind != "gear" or cfg.policy.is_fp16:
        return False
    scheme, group = cfg.k_scheme()
    if scheme != "per_channel":
        return False
    return (cfg.chunk if group is None else group) == cfg.chunk


def streaming_prefill_pipeline(cfg: CacheConfig, cache, n: int, chunk_xs,
                               tail_x, project, scale: float,
                               key: jax.Array | None = None,
                               fused: str = "auto", start_chunk: int = 0,
                               tail_is_padded: bool = False, true_n=None):
    """Shared driver of the streaming chunked prefill (compress-as-you-go).

    ``chunk_xs`` is a pytree of per-chunk inputs with a leading ``[C']``
    axis and ``tail_x`` the leftover-token inputs (or None);
    ``project(x) -> (q [B, Hq, T, Dh], k, v [B, H, T, Dh])`` maps either to
    the chunk's attention inputs — the model layer passes the raw residual-
    stream chunk and projects Q/K/V *inside the scans*, so the full-sequence
    FP16 K/V never exists.  Two carry-free ``lax.scan`` passes (loop fission
    of the compress-as-you-go loop — same dataflow, no per-step cache-carry
    copies):

    1. **Compression scan** — each chunk runs its compression event
       (:func:`_compress_chunks`, optionally through the fused
       ``gear_compress`` kernel); the stacked outputs are stored into the
       packed arrays in one shot (identical layout to the monolithic
       batched event).
    2. **Attend scan** — each chunk's queries attend the compressed history
       *before* their own chunk (scores masked at ``c · n_b``, factored
       ``gear_decode`` machinery) plus the in-flight FP16 chunk via a
       two-piece online softmax (:func:`repro.kernels.ops.gear_attend_block`),
       in segments over static chunk-prefix views.  Masking makes this
       bitwise identical to interleaving the two scans.

    Leftover tokens attend the same way (against the prefix view of the
    populated chunks only) and land in the FP16 streaming buffer.  Returns
    (cache, attn_out [B, Hq, n, Dh]).

    ``start_chunk`` > 0 runs the same pipeline as a **suffix** over a cache
    whose first ``start_chunk`` chunks are already populated (spliced from
    the prefix cache): new chunks are stored from chunk ``start_chunk``,
    every attend sees the cached chunks as compressed history (the global
    extent masks make each suffix chunk's output bit-identical to the cold
    run that computed those chunks itself), and the final length covers
    prefix + suffix.  ``n`` stays the *suffix* token count.

    ``tail_is_padded`` is the length-bucketing hook (mixed-length serving):
    ``n`` must then be a chunk multiple and the LAST ``n_b`` block of the
    inputs is a right-padded tail — ``true_n`` (traced, ``<= n``) real
    tokens overall, pad garbage after.  The tail block is kept OUT of the
    compression scan (no garbage chunk is ever closed or admitted to the
    prefix cache) and lands in the FP16 streaming buffer instead; causal
    masking keeps pad keys out of every real query's scores, and decode
    masks buffer rows at ``length`` — which is set from ``true_n`` — so
    the pad rows stay exact zeros forever after.
    """
    if not streaming_supported(cfg):
        raise ValueError(
            "streaming prefill requires a GEAR cache with per-channel K "
            f"stats at chunk granularity (got kind={cfg.kind!r}, "
            f"k_scheme={cfg.k_scheme()!r}, chunk={cfg.chunk})")
    from repro.kernels import ops as kernel_ops  # lazy: kernels import us

    if key is None:
        key = jax.random.PRNGKey(0)
    pol = cfg.policy
    nb = cfg.chunk
    if tail_is_padded and n % nb:
        raise ValueError(f"padded-tail prefill needs n % n_b == 0 (n={n}, "
                         f"n_b={nb})")
    C_new = n // nb - 1 if tail_is_padded else n // nb
    n_full = C_new * nb
    rem = n - n_full
    # A padded tail holds >= 1 real token, so the tightest static bound on
    # the true length is n - nb + 1; the engine re-checks the exact raw
    # length host-side at admission.
    n_min = n - nb + 1 if tail_is_padded else n
    if start_chunk * nb + n_min > cfg.capacity:
        raise ValueError(
            f"suffix prefill past capacity: start_chunk {start_chunk} * "
            f"{nb} + {n} tokens > capacity {cfg.capacity}")
    force = fused == "interpret"
    oracle = fused == "off"          # pin the jnp oracles even on TPU
    B = cache.length.shape[0]
    Dh = cfg.head_dim

    outs = []
    if C_new:
        def body_compress(_, x_c):
            _, k_c, v_c = project(x_c)
            comp = _compress_chunks(
                cfg, k_c[:, :, None].astype(jnp.float32),
                v_c[:, :, None].astype(jnp.float32), pol.rank, key, fused=fused)
            return None, comp

        _, comp_s = jax.lax.scan(body_compress, None, chunk_xs)
        upd = {f.name: getattr(cache, f.name)
               for f in dataclasses.fields(GEARLayerCache)}
        cache = GEARLayerCache(**_assemble_scanned_chunks(cfg, upd, comp_s,
                                                          n_full, start_chunk))

        out_parts = []
        # Segment over the GLOBAL chunk range, then clip to the suffix: a
        # suffix chunk attends through exactly the prefix-view width the
        # cold run's schedule gave it, so the score shapes — and therefore
        # the float bits XLA's width-dependent reductions produce — match
        # the cold run, not just the masked math (start_chunk == 0 reduces
        # to plain segmentation of C_new).
        for g_lo, g_hi in _attend_segments(start_chunk + C_new):
            lo = max(g_lo - start_chunk, 0)
            hi = g_hi - start_chunk
            if hi <= lo:
                continue               # segment fully inside the cached prefix
            view = chunk_prefix_view(cfg, cache, g_hi)

            def body_attend(_, xs, view=view):
                c, x_c = xs
                q_c, k_c, v_c = project(x_c)
                out_c = kernel_ops.gear_attend_block(
                    cfg, view, q_c, k_c, v_c, (start_chunk + c) * nb, nb,
                    scale, force_kernel=force, interpret=force,
                    force_oracle=oracle)
                return None, out_c

            seg_xs = jax.tree.map(lambda t: t[lo:hi], chunk_xs)
            _, o = jax.lax.scan(
                body_attend, None,
                (jnp.arange(lo, hi, dtype=jnp.int32), seg_xs))
            out_parts.append(o)
        outs_s = jnp.concatenate(out_parts, axis=0)
        Hq = outs_s.shape[2]
        outs.append(jnp.moveaxis(outs_s, 0, 2).reshape(B, Hq, n_full, Dh))
    if rem:
        q_t, k_t, v_t = project(tail_x)
        view = chunk_prefix_view(cfg, cache, max(start_chunk + C_new, 1))
        out_t = kernel_ops.gear_attend_block(
            cfg, view, q_t, k_t, v_t, start_chunk * nb + n_full, rem, scale,
            force_kernel=force, interpret=force, force_oracle=oracle)
        z4 = (0, 0, 0, 0)
        cache = dataclasses.replace(
            cache,
            buf_k=jax.lax.dynamic_update_slice(
                cache.buf_k, k_t.astype(cache.buf_k.dtype), z4),
            buf_v=jax.lax.dynamic_update_slice(
                cache.buf_v, v_t.astype(cache.buf_v.dtype), z4))
        outs.append(out_t)
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    n_real = n if true_n is None else true_n
    cache = dataclasses.replace(
        cache,
        length=jnp.full((B,), jnp.asarray(start_chunk * nb + n_real,
                                          jnp.int32)))
    return cache, out


def streaming_prefill_layer_cache(cfg: CacheConfig, cache, q: jnp.ndarray,
                                  k: jnp.ndarray, v: jnp.ndarray,
                                  scale: float, key: jax.Array | None = None,
                                  fused: str = "auto", start_chunk: int = 0,
                                  tail_is_padded: bool = False, true_n=None):
    """Streaming chunked prefill over precomputed q/k/v (reference entry).

    q: [B, Hq, n, Dh]; k, v: [B, H, n, Dh] — sliced per chunk into
    :func:`streaming_prefill_pipeline` (the model layer instead projects
    per chunk inside the scans; see
    :func:`repro.models.attention.attention_prefill_streaming`).

    Chunk compression is bit-identical to :func:`prefill_layer_cache`'s
    batched event (batch-invariant keys + per-chunk-independent math), so
    the resulting cache is bit-identical to a monolithic prefill of the
    same tokens; only the attention output differs (history is attended in
    compressed form — the same semantics decode already has).

    Returns (cache, attn_out [B, Hq, n, Dh] in q's dtype).
    ``fused``: "auto"/"off" (kernels on TPU, jnp oracles elsewhere) or
    "interpret" (force the Pallas kernels in interpret mode).
    ``start_chunk`` > 0 treats q/k/v as the *suffix* after that many
    already-populated chunks of ``cache`` (the prefix-cache splice path).
    ``tail_is_padded`` / ``true_n`` take the bucketed mixed-length path
    (see :func:`streaming_prefill_pipeline`).
    """
    pol_nb = cfg.chunk
    B, Hq, n, Dh = q.shape
    H = cfg.kv_heads
    C_new = n // pol_nb - 1 if tail_is_padded else n // pol_nb
    n_full = C_new * pol_nb

    def stack(x, heads):
        return jnp.moveaxis(
            x[:, :, :n_full].reshape(B, heads, C_new, pol_nb, Dh), 2, 0)

    chunk_xs = (stack(q, Hq), stack(k, H), stack(v, H)) if C_new else None
    tail_x = ((q[:, :, n_full:], k[:, :, n_full:], v[:, :, n_full:])
              if n > n_full else None)
    return streaming_prefill_pipeline(cfg, cache, n, chunk_xs, tail_x,
                                      lambda x: x, scale, key, fused,
                                      start_chunk, tail_is_padded, true_n)


def append_token(cfg: CacheConfig, cache, k_t: jnp.ndarray, v_t: jnp.ndarray,
                 key: jax.Array | None = None):
    """Append one token's K/V [B, H, Dh] per slot; compress full buffers.

    Each slot advances at its own ``length[b]``: writes land at per-slot
    offsets, and a slot whose streaming buffer just filled gets its chunk
    compressed and scattered into packed storage (slots not at a chunk
    boundary drop their writes).  Past capacity the packed / fp16 / window
    writes drop, but the GEAR streaming buffer keeps ring-wrapping, so a
    live request must never outgrow capacity (the scheduler rejects it at
    submit time); an *idle* slot may keep riding the batched step with
    garbage state until it is respliced, since a splice rewrites the row.
    """
    if cfg.kind == "fp16":
        knew = _slot_rows_update(cache.k, k_t[:, :, None, :], cache.length)
        vnew = _slot_rows_update(cache.v, v_t[:, :, None, :], cache.length)
        return FP16LayerCache(k=knew, v=vnew, length=cache.length + 1)
    if cfg.kind == "window":
        W = cfg.window
        slot = cache.length % W
        knew = _slot_rows_update(cache.k, k_t[:, :, None, :], slot)
        vnew = _slot_rows_update(cache.v, v_t[:, :, None, :], slot)
        B = cache.pos.shape[0]
        pos = cache.pos.at[jnp.arange(B), slot].set(cache.length)
        return WindowLayerCache(k=knew, v=vnew, pos=pos, length=cache.length + 1)

    pol = cfg.policy
    nb = cfg.chunk
    if key is None:
        key = jax.random.PRNGKey(0)
    buf_pos = cache.length % nb
    buf_k = _slot_rows_update(cache.buf_k, k_t[:, :, None, :], buf_pos)
    buf_v = _slot_rows_update(cache.buf_v, v_t[:, :, None, :], buf_pos)
    cache = dataclasses.replace(cache, buf_k=buf_k, buf_v=buf_v, length=cache.length + 1)

    def compress(c):
        # Per-slot chunk of the buffer just filled; slots not at a boundary
        # compute a throwaway compression whose writes are dropped.
        need = (c.length % nb == 0) & (c.length > 0) & (c.length <= cfg.capacity)
        cidx = jnp.maximum(c.length - 1, 0) // nb
        B, H, _, Dh = c.buf_k.shape
        kc = c.buf_k[:, :, None, :, :].astype(jnp.float32)  # [B,H,1,nb,Dh]
        vc = c.buf_v[:, :, None, :, :].astype(jnp.float32)
        # NOTE: the compression key is slot- and step-invariant so that a
        # request spliced into a live batch reproduces its solo compression
        # bit-for-bit (see DESIGN.md §splice isolation).
        comp = _compress_chunks(cfg, kc, vc, pol.rank_decode, key)
        upd = {f.name: getattr(c, f.name) for f in dataclasses.fields(GEARLayerCache)}
        tok0 = cidx * nb
        upd["k_packed"] = _slot_rows_update(
            upd["k_packed"], comp["k_packed"].reshape(B, H, nb, -1), tok0, need)
        upd["v_packed"] = _slot_rows_update(
            upd["v_packed"], comp["v_packed"].reshape(B, H, nb, -1), tok0, need)
        for kv in ("k", "v"):
            stat_s = _flatten_stat(cfg, comp[f"{kv}_scale"], kv)
            stat_z = _flatten_stat(cfg, comp[f"{kv}_zero"], kv)
            rows_per_chunk = stat_s.shape[2]
            upd[f"{kv}_scale"] = _slot_rows_update(
                upd[f"{kv}_scale"], stat_s, cidx * rows_per_chunk, need)
            upd[f"{kv}_zero"] = _slot_rows_update(
                upd[f"{kv}_zero"], stat_z, cidx * rows_per_chunk, need)
            if pol.use_lowrank:
                a = comp[f"{kv}_a"].reshape(B, H, nb, pol.rank)
                upd[f"{kv}_a"] = _slot_rows_update(upd[f"{kv}_a"], a, tok0, need)
                upd[f"{kv}_b"] = _slot_rows_update(
                    upd[f"{kv}_b"], comp[f"{kv}_b"], cidx, need)
            if pol.use_sparse:
                sv, si = comp[f"{kv}_sp_val"], comp[f"{kv}_sp_idx"]
                if kv == "v" or cfg.k_scheme()[0] != "per_channel":
                    sv = sv.reshape(B, H, nb, sv.shape[-1])
                    si = si.reshape(B, H, nb, si.shape[-1])
                    upd[f"{kv}_sp_val"] = _slot_rows_update(upd[f"{kv}_sp_val"], sv, tok0, need)
                    upd[f"{kv}_sp_idx"] = _slot_rows_update(upd[f"{kv}_sp_idx"], si, tok0, need)
                else:
                    upd[f"{kv}_sp_val"] = _slot_rows_update(upd[f"{kv}_sp_val"], sv, cidx, need)
                    upd[f"{kv}_sp_idx"] = _slot_rows_update(upd[f"{kv}_sp_idx"], si, cidx, need)
        return GEARLayerCache(**upd)

    any_boundary = jnp.any((cache.length % nb == 0) & (cache.length > 0)
                           & (cache.length <= cfg.capacity))
    return jax.lax.cond(any_boundary, compress, lambda c: c, cache)


# ---------------------------------------------------------------------------
# Attention over the compressed cache


def _expand_stat(cfg: CacheConfig, stat: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Expand compact scale/zero rows back to [B, H, S, Dh]."""
    scheme, group = cfg.k_scheme() if kind == "k" else cfg.v_scheme()
    B, H = stat.shape[0], stat.shape[1]
    S, Dh = cfg.capacity, cfg.head_dim
    if scheme == "per_channel":
        g = cfg.chunk if group is None else group
        x = jnp.repeat(stat[:, :, :, None, :], g, axis=3)
        return x.reshape(B, H, S, Dh)
    g = Dh if group is None else group
    x = jnp.repeat(stat[:, :, :, :, None], g, axis=4)
    return x.reshape(B, H, S, Dh)


def _dequant_backbone(cfg: CacheConfig, packed, scale, zero, kind: str,
                      dtype=jnp.float32) -> jnp.ndarray:
    codes = packing.unpack(packed, cfg.policy.bits, cfg.head_dim).astype(dtype)
    s = _expand_stat(cfg, scale.astype(dtype), kind)
    z = _expand_stat(cfg, zero.astype(dtype), kind)
    return codes * s + z


def _sparse_dense(cfg: CacheConfig, sp_val, sp_idx, kind: str) -> jnp.ndarray:
    """Densify cached outliers to [B, H, S, Dh] (jnp path only)."""
    B, H = sp_val.shape[0], sp_val.shape[1]
    S, Dh, nb, C = cfg.capacity, cfg.head_dim, cfg.chunk, cfg.n_chunks
    per_channel = kind == "k" and cfg.k_scheme()[0] == "per_channel"
    if per_channel:
        # sp_* [B,H,C,Dh,2k]: token index within chunk
        kk = sp_val.shape[-1]
        onehot = sp_idx[..., None] == jnp.arange(nb)  # [B,H,C,Dh,2k,nb]
        dense = jnp.einsum("bhcdk,bhcdkn->bhcnd", sp_val.astype(jnp.float32),
                           onehot.astype(jnp.float32))
        return dense.reshape(B, H, S, Dh)
    # sp_* [B,H,S,2k]: channel index within Dh
    onehot = sp_idx[..., None] == jnp.arange(Dh)  # [B,H,S,2k,Dh]
    return jnp.einsum("bhsk,bhskd->bhsd", sp_val.astype(jnp.float32),
                      onehot.astype(jnp.float32))


def _lowrank_dense(cfg: CacheConfig, a, b) -> jnp.ndarray:
    """Materialize per-chunk A·Bᵀ to [B, H, S, Dh] (test/debug path)."""
    B, H = a.shape[0], a.shape[1]
    C, nb, Dh, r = cfg.n_chunks, cfg.chunk, cfg.head_dim, cfg.policy.rank
    ac = a.reshape(B, H, C, nb, r).astype(jnp.float32)
    return jnp.einsum("bhcnr,bhcdr->bhcnd", ac, b.astype(jnp.float32)).reshape(B, H, S := cfg.capacity, Dh)


def dense_kv(cfg: CacheConfig, cache) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reconstruct dense K̂/V̂ [B, H, S(+buffer), Dh] — reference/debug path.

    Buffer tokens are appended in fp16, so positions < length round-trip.
    """
    if cfg.kind == "fp16":
        return cache.k.astype(jnp.float32), cache.v.astype(jnp.float32)
    if cfg.kind == "window":
        return cache.k.astype(jnp.float32), cache.v.astype(jnp.float32)
    pol = cfg.policy
    k_hat = _dequant_backbone(cfg, cache.k_packed, cache.k_scale, cache.k_zero, "k")
    v_hat = _dequant_backbone(cfg, cache.v_packed, cache.v_scale, cache.v_zero, "v")
    if pol.use_lowrank:
        k_hat = k_hat + _lowrank_dense(cfg, cache.k_a, cache.k_b)
        v_hat = v_hat + _lowrank_dense(cfg, cache.v_a, cache.v_b)
    if pol.use_sparse:
        k_hat = k_hat + _sparse_dense(cfg, cache.k_sp_val, cache.k_sp_idx, "k")
        v_hat = v_hat + _sparse_dense(cfg, cache.v_sp_val, cache.v_sp_idx, "v")
    # overlay buffered (uncompressed) tokens — per-slot buffer windows
    nb = cfg.chunk
    n_comp = (cache.length // nb) * nb                       # [B]
    tok = jnp.arange(cfg.capacity)
    buf_slot = tok[None, :] - n_comp[:, None]                # [B, S]
    in_buf = (buf_slot >= 0) & (buf_slot < nb) & (tok[None, :] < cache.length[:, None])
    bslot = jnp.clip(buf_slot, 0, nb - 1)
    k_buf = jnp.take_along_axis(cache.buf_k.astype(jnp.float32),
                                bslot[:, None, :, None], axis=2)
    v_buf = jnp.take_along_axis(cache.buf_v.astype(jnp.float32),
                                bslot[:, None, :, None], axis=2)
    mask = in_buf[:, None, :, None]
    k_hat = jnp.where(mask, k_buf, k_hat)
    v_hat = jnp.where(mask, v_buf, v_hat)
    valid = (tok[None, :] < cache.length[:, None])[:, None, :, None]
    return k_hat * valid, v_hat * valid


def attend(cfg: CacheConfig, cache, q: jnp.ndarray, scale: float,
           use_factored: bool = True) -> jnp.ndarray:
    """Decode attention of one query token over the cache.

    q: [B, Hq, Dh] with Hq = G * kv_heads (GQA).  Returns [B, Hq, Dh].
    ``use_factored`` selects the factored low-rank/sparse score path (the
    paper's separate forward path); False falls back to dense reconstruction.
    """
    B, Hq, Dh = q.shape
    H = cfg.kv_heads
    G = Hq // H
    qf = q.astype(jnp.float32).reshape(B, H, G, Dh)

    if cfg.kind == "window":
        kf, vf = cache.k.astype(jnp.float32), cache.v.astype(jnp.float32)
        scores = jnp.einsum("bhgd,bhwd->bhgw", qf, kf) * scale
        valid = (cache.pos >= 0) & (cache.pos < cache.length[:, None])  # [B, W]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgw,bhwd->bhgd", w, vf)
        return out.reshape(B, Hq, Dh).astype(q.dtype)

    if cfg.kind == "fp16" or not use_factored:
        kf, vf = dense_kv(cfg, cache)
        scores = jnp.einsum("bhgd,bhsd->bhgs", qf, kf) * scale
        valid = jnp.arange(cfg.capacity)[None, :] < cache.length[:, None]  # [B, S]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgs,bhsd->bhgd", w, vf)
        return out.reshape(B, Hq, Dh).astype(q.dtype)

    pol = cfg.policy
    nb, C, S = cfg.chunk, cfg.n_chunks, cfg.capacity
    n_comp = (cache.length // nb) * nb        # [B] per-slot compressed extent
    n_buf = cache.length - n_comp             # [B] per-slot buffer fill
    cdt = jnp.bfloat16  # dequant/compute dtype; accumulations stay f32
    f32 = jnp.float32
    qc = qf.astype(cdt)

    # --- scores over the compressed region -------------------------------
    k_codes = packing.unpack(cache.k_packed, pol.bits, Dh).astype(cdt)
    if cfg.k_scheme()[0] == "per_channel":
        g = cfg.chunk if cfg.k_scheme()[1] is None else cfg.k_scheme()[1]
        rows = S // g
        sc = cache.k_scale.astype(cdt).reshape(B, H, rows, Dh)
        zr = cache.k_zero.astype(cdt).reshape(B, H, rows, Dh)
        # scores = (q ⊙ scale_row)·codes + q·zero_row  per row-group of g tokens
        q_sc = jnp.einsum("bhgd,bhrd->bhgrd", qc, sc)
        codes_r = k_codes.reshape(B, H, rows, g, Dh)
        s_bb = jnp.einsum("bhgrd,bhrnd->bhgrn", q_sc, codes_r,
                          preferred_element_type=cdt)
        s_bb = s_bb + jnp.einsum("bhgd,bhrd->bhgr", qc, zr,
                                 preferred_element_type=cdt)[..., None]
        s_bb = s_bb.reshape(B, H, G, S)
    else:
        k_hat = _dequant_backbone(cfg, cache.k_packed, cache.k_scale,
                                  cache.k_zero, "k", dtype=cdt)
        s_bb = jnp.einsum("bhgd,bhsd->bhgs", qc, k_hat, preferred_element_type=cdt)

    if pol.use_lowrank:
        # factored path: (q·B_c)·A_cᵀ per chunk
        qb = jnp.einsum("bhgd,bhcdr->bhgcr", qc, cache.k_b.astype(cdt))
        a_c = cache.k_a.astype(cdt).reshape(B, H, C, nb, pol.rank)
        s_lr = jnp.einsum("bhgcr,bhcnr->bhgcn", qb, a_c,
                          preferred_element_type=cdt).reshape(B, H, G, S)
        s_bb = s_bb + s_lr
    if pol.use_sparse:
        if cfg.k_scheme()[0] == "per_channel":
            # Densify K outliers with a vals-only scatter (index tensor has
            # no G or Dh-column blowup), then one q·sp_dense dot — §Perf
            # iterations 3+5.
            K2 = cache.k_sp_val.shape[-1]
            rows_k = B * H * C * Dh
            sp_cdn = jnp.zeros((rows_k, nb), cdt).at[
                jnp.arange(rows_k, dtype=jnp.int32)[:, None],
                cache.k_sp_idx.reshape(rows_k, K2)].add(
                cache.k_sp_val.astype(cdt).reshape(rows_k, K2))
            sp_cdn = sp_cdn.reshape(B, H, C, Dh, nb)
            s_sp = jnp.einsum("bhgd,bhcdn->bhgcn", qc, sp_cdn,
                              preferred_element_type=cdt)
            s_bb = s_bb + s_sp.reshape(B, H, G, S)
        else:
            sp_dense = _sparse_dense(cfg, cache.k_sp_val, cache.k_sp_idx, "k")
            s_bb = s_bb + jnp.einsum("bhgd,bhsd->bhgs", qf, sp_dense)

    # --- buffer scores -----------------------------------------------------
    s_buf = jnp.einsum("bhgd,bhnd->bhgn", qc, cache.buf_k.astype(cdt),
                       preferred_element_type=cdt)

    # --- masks + two-piece online softmax (no concat copy; §Perf iter 5) ----
    neg = jnp.asarray(-1e30, s_bb.dtype)
    m_bb = (jnp.arange(S)[None, :] < n_comp[:, None])[:, None, None, :]
    m_buf = (jnp.arange(nb)[None, :] < n_buf[:, None])[:, None, None, :]
    s_bb = jnp.where(m_bb, s_bb * scale, neg)
    s_buf = jnp.where(m_buf, s_buf * scale, neg)
    m_all = jnp.maximum(jnp.max(s_bb, axis=-1), jnp.max(s_buf, axis=-1))[..., None]
    e_bb = jnp.exp((s_bb - m_all).astype(f32))
    e_buf = jnp.exp((s_buf - m_all).astype(f32))
    denom = jnp.sum(e_bb, axis=-1, keepdims=True) + jnp.sum(e_buf, axis=-1, keepdims=True)
    w_c = e_bb / denom
    w_buf = e_buf / denom

    # --- weighted values -----------------------------------------------------
    w_cb = w_c.astype(cdt)
    v_codes = packing.unpack(cache.v_packed, pol.bits, Dh).astype(cdt)
    v_sc = _expand_stat(cfg, cache.v_scale.astype(cdt), "v")
    v_zr = _expand_stat(cfg, cache.v_zero.astype(cdt), "v")
    v_hat = v_codes * v_sc + v_zr
    if pol.use_sparse:
        # densify V outliers with a vals-only scatter (no per-G duplication)
        # and fold into the backbone dequant — the add fuses into the dot's
        # operand, so the only extra traffic is the tiny update set
        # (§Perf iteration 4).
        K2v = cache.v_sp_val.shape[-1]
        rows_v = B * H * S
        sp_dense_v = jnp.zeros((rows_v, Dh), cdt).at[
            jnp.arange(rows_v, dtype=jnp.int32)[:, None],
            cache.v_sp_idx.reshape(rows_v, K2v)].add(
            cache.v_sp_val.astype(cdt).reshape(rows_v, K2v))
        v_hat = v_hat + sp_dense_v.reshape(B, H, S, Dh)
    out = jnp.einsum("bhgs,bhsd->bhgd", w_cb, v_hat,
                     preferred_element_type=f32)
    if pol.use_lowrank:
        # factored: (w·A_c)·B_cᵀ per chunk
        w_chunk = w_cb.reshape(B, H, G, C, nb)
        wa = jnp.einsum("bhgcn,bhcnr->bhgcr", w_chunk,
                        cache.v_a.astype(cdt).reshape(B, H, C, nb, pol.rank))
        out = out + jnp.einsum("bhgcr,bhcdr->bhgd", wa, cache.v_b.astype(cdt),
                               preferred_element_type=f32)
    out = out + jnp.einsum("bhgn,bhnd->bhgd", w_buf.astype(cdt),
                           cache.buf_v.astype(cdt), preferred_element_type=f32)
    return out.reshape(B, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Prefix-chunk extraction / splicing (cross-request prefix cache)


def _chunk_row_axes(cfg: CacheConfig) -> dict[str, tuple[int, int]]:
    """Chunk-indexed row layout of every GEAR cache array.

    Maps field name -> ``(rows_per_chunk, row_axis_from_end)``: chunk ``c``
    of a cache array occupies rows ``[c * rows_per_chunk, (c+1) *
    rows_per_chunk)`` along the given axis (counted from the end, so the
    same spec serves plain ``[B, H, ...]`` layer caches and the engine's
    repeat-stacked ``[R, B, H, ...]`` leaves).  Buffer / length leaves are
    deliberately absent: they are per-slot streaming state, never part of a
    chunk.
    """
    if cfg.kind != "gear":
        raise ValueError(f"prefix chunks require a GEAR cache, got {cfg.kind!r}")
    pol = cfg.policy
    nb = cfg.chunk
    C = cfg.n_chunks
    spec: dict[str, tuple[int, int]] = {
        "k_packed": (nb, -2), "v_packed": (nb, -2),
        "k_scale": (_k_stat_rows(cfg)[0] // C, -2),
        "k_zero": (_k_stat_rows(cfg)[0] // C, -2),
        "v_scale": (_v_stat_rows(cfg)[0] // C, -2),
        "v_zero": (_v_stat_rows(cfg)[0] // C, -2),
    }
    if pol.use_lowrank:
        spec.update(k_a=(nb, -2), v_a=(nb, -2), k_b=(1, -3), v_b=(1, -3))
    if pol.use_sparse:
        k_chan = cfg.k_scheme()[0] == "per_channel"
        spec.update(k_sp_val=(1, -3) if k_chan else (nb, -2),
                    k_sp_idx=(1, -3) if k_chan else (nb, -2),
                    v_sp_val=(nb, -2), v_sp_idx=(nb, -2))
    return spec


def extract_prefix_chunks(cfg: CacheConfig, cache, n_chunks: int,
                          start_chunk: int = 0) -> list[dict]:
    """Slice chunks ``[start_chunk, start_chunk + n_chunks)`` of a GEAR
    layer cache into independent per-chunk payload dicts.

    Works on a plain ``[B, H, ...]`` layer cache or on one position of the
    engine's repeat-stacked tree (leaves ``[R, B, H, ...]``): the chunk row
    axes are addressed from the end, so extra leading dims pass through.
    Each payload holds every compressed-array slice of one chunk (packed
    codes, quant stats, low-rank factors, outliers) — exactly the state
    :func:`splice_prefix_chunks` needs to reproduce the chunk in any slot
    of any cache with the same geometry.  Buffer and length are not
    extracted (a cached prefix is always chunk-aligned).
    """
    spec = _chunk_row_axes(cfg)
    out = []
    for c in range(start_chunk, start_chunk + n_chunks):
        payload = {}
        for field, (rpc, ax) in spec.items():
            arr = getattr(cache, field)
            idx = [slice(None)] * arr.ndim
            idx[arr.ndim + ax] = slice(c * rpc, (c + 1) * rpc)
            payload[field] = arr[tuple(idx)]
        out.append(payload)
    return out


def splice_prefix_chunks(cfg: CacheConfig, cache, slot, chunks: list[dict],
                         start_chunk: int = 0, batch_axis: int = 0):
    """Write per-chunk payloads (from :func:`extract_prefix_chunks`) into
    batch row ``slot`` of ``cache`` as chunks ``[start_chunk, start_chunk +
    len(chunks))``.

    The payloads are concatenated per field and written with one
    ``dynamic_update_slice`` each — the same batch-row write the slot-
    splice protocol uses.  ``batch_axis`` is 0 for a single layer cache and
    1 for the engine's repeat-stacked ``[R, B, ...]`` leaves.  ``length``
    is left untouched: the caller owns it (suffix prefill sets it to
    prefix + suffix).  Pass-through leaves the chunk spec does not cover
    (streaming buffer, length) alias ``cache``'s arrays, so the result
    must NOT be donated into a jitted program while ``cache`` (e.g. the
    engine's memoized empty scaffold) is still live.
    """
    if not chunks:
        return cache
    slot = jnp.asarray(slot, jnp.int32)
    spec = _chunk_row_axes(cfg)
    upd = {}
    for field, (rpc, ax) in spec.items():
        dst = getattr(cache, field)
        row_axis = dst.ndim + ax
        parts = [ch[field] for ch in chunks]
        seg = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=row_axis)
        starts = [jnp.asarray(0, jnp.int32)] * dst.ndim
        starts[batch_axis] = slot
        starts[row_axis] = jnp.asarray(start_chunk * rpc, jnp.int32)
        upd[field] = jax.lax.dynamic_update_slice(
            dst, seg.astype(dst.dtype), tuple(starts))
    return dataclasses.replace(cache, **upd)


class NumericFault(RuntimeError):
    """A compressed chunk failed the NaN/Inf finiteness guard.

    Raised at the two trust boundaries where a closed chunk becomes shared
    state: the engine's post-prefill guard (before the batch-1 cache is
    spliced into the live batched tree) and :meth:`ChunkStore.put` when the
    prefix cache validates payloads on insert.  Quarantine semantics: the
    poisoned request fails, its slot is reset and pages released, and no
    trie node is created — co-batched requests never see the bad values.
    """


def tree_finite(tree) -> jnp.ndarray:
    """Scalar bool: every float/complex leaf of ``tree`` is fully finite.

    Integer leaves (packed codes, sparse indices, lengths, page tables)
    are skipped — they cannot hold NaN/Inf and the guard stays one fused
    reduction over the few inexact leaves (quant stats, low-rank factors,
    outlier values, streaming buffer).  Safe under ``jax.jit``; returns
    True for a tree with no inexact leaves.
    """
    leaves = [x for x in jax.tree_util.tree_leaves(tree)
              if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)]
    ok = jnp.asarray(True)
    for leaf in leaves:
        ok = jnp.logical_and(ok, jnp.isfinite(leaf).all())
    return ok


# ---------------------------------------------------------------------------
# Paged compressed KV pool (vLLM-style block tables over GEAR chunks)
#
# One **page** holds one n_b-token chunk's compressed fields for one layer:
# every chunk-indexed array of the dense layout (see ``_chunk_row_axes``)
# gets a pooled twin whose batch axis is replaced by a page axis and whose
# chunk-row axis is sliced to one chunk's rows.  A per-slot **block table**
# ``[B, C]`` of page ids maps logical chunk ``c`` of slot ``b`` to its pool
# page; page 0 is the permanently-zero reserved page, so table entries past
# a slot's allocated extent read as the dense layout's zeros — which is what
# makes ``paged_to_dense`` *bitwise* equal to the dense-slot cache (the
# allocator zeroes fresh pages at admission to keep the invariant; see
# DESIGN.md §5).  The streaming buffer and ``length`` stay per-slot: only
# closed (immutable) chunks live in the pool, which is why prefix-cache
# sharing is pure refcounting with no copy-on-write copies ever needed.


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "k_packed", "k_scale", "k_zero", "v_packed", "v_scale", "v_zero",
        "k_a", "k_b", "v_a", "v_b",
        "k_sp_val", "k_sp_idx", "v_sp_val", "v_sp_idx",
        "buf_k", "buf_v", "length",
    ],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class PagedGEARLayerCache:
    """GEAR layer cache with pooled chunk storage.

    Pooled fields are ``[P, ...page]`` (P pages shared by every slot); the
    streaming buffer ``[B, H, n_b, Dh]`` and ``length [B]`` remain per-slot.
    The block table addressing the pool is *engine-owned metadata* passed
    alongside (like ``pos``), not cache state — it changes only at
    admission/release, never inside a decode step.
    """
    k_packed: Any; k_scale: Any; k_zero: Any
    v_packed: Any; v_scale: Any; v_zero: Any
    k_a: Any; k_b: Any; v_a: Any; v_b: Any
    k_sp_val: Any; k_sp_idx: Any; v_sp_val: Any; v_sp_idx: Any
    buf_k: Any; buf_v: Any
    length: Any


_POOLED_FIELDS = ("k_packed", "k_scale", "k_zero", "v_packed", "v_scale",
                  "v_zero", "k_a", "k_b", "v_a", "v_b",
                  "k_sp_val", "k_sp_idx", "v_sp_val", "v_sp_idx")


def paged_supported(cfg: CacheConfig) -> bool:
    """True when this layer's cache can live in the paged pool.

    Any GEAR layout qualifies (the gather path reassembles the dense layout
    bit-for-bit regardless of quant scheme); fp16 and window caches have no
    chunk-decomposable state and stay dense — as do RWKV / SSM recurrent
    states, which the serving layer never pages (DESIGN.md §5).
    """
    return cfg.kind == "gear" and not cfg.policy.is_fp16


def page_field_shapes(cfg: CacheConfig, dtype=jnp.bfloat16) -> dict:
    """Per-field ``(page_shape, dtype)`` of one pool page.

    Derived from the dense batch-1 geometry: drop the batch axis, slice the
    chunk-row axis (``_chunk_row_axes``) to one chunk's rows.  E.g.
    ``k_packed [1, H, S, Lp] -> (H, n_b, Lp)``, ``k_b [1, H, C, Dh, r] ->
    (H, 1, Dh, r)``.
    """
    cfg1 = cfg if cfg.batch == 1 else dataclasses.replace(cfg, batch=1)
    abs1 = jax.eval_shape(lambda: init_layer_cache(cfg1, dtype))
    out = {}
    for field, (rpc, ax) in _chunk_row_axes(cfg).items():
        leaf = getattr(abs1, field)
        if leaf is None:
            out[field] = None
            continue
        shape = list(leaf.shape[1:])          # drop the batch axis
        shape[len(shape) + ax] = rpc          # row axis counted from the end
        out[field] = (tuple(shape), leaf.dtype)
    return out


def page_nbytes(cfg: CacheConfig, dtype=jnp.bfloat16) -> int:
    """Bytes of one pool page for ONE layer of this geometry."""
    total = 0
    for spec in page_field_shapes(cfg, dtype).values():
        if spec is None:
            continue
        shape, dt = spec
        total += int(jnp.dtype(dt).itemsize) * functools.reduce(
            lambda a, b: a * b, shape, 1)
    return total


def init_paged_layer_cache(cfg: CacheConfig, n_pages: int,
                           dtype=jnp.bfloat16) -> PagedGEARLayerCache:
    """Zero pool of ``n_pages`` pages + per-slot buffers for ``cfg.batch``.

    Page 0 is the reserved zero page (never allocated): a fresh cache with
    an all-zero block table gathers back to exactly the dense zero cache.
    """
    if not paged_supported(cfg):
        raise ValueError(f"paged layout requires a GEAR cache, got {cfg.kind!r}")
    if n_pages < 2:
        raise ValueError(f"need >= 2 pages (page 0 is reserved), got {n_pages}")
    B, H, Dh = cfg.batch, cfg.kv_heads, cfg.head_dim
    shapes = page_field_shapes(cfg, dtype)
    fields = {}
    for field in _POOLED_FIELDS:
        spec = shapes.get(field)
        fields[field] = (None if spec is None
                         else jnp.zeros((n_pages,) + spec[0], spec[1]))
    return PagedGEARLayerCache(
        **fields,
        buf_k=jnp.zeros((B, H, cfg.chunk, Dh), dtype),
        buf_v=jnp.zeros((B, H, cfg.chunk, Dh), dtype),
        length=jnp.zeros((B,), jnp.int32),
    )


def paged_to_dense(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                   block_tables: jnp.ndarray) -> GEARLayerCache:
    """Gather the pool through ``block_tables [B, C]`` into a dense cache.

    Bitwise equal to the dense-slot layout under the allocator's zero-page
    invariant (unallocated / unwritten table entries point at zeroed
    pages), so the portable decode path is literally ``attend(gather(...))``
    and cache-parity tests can compare arrays directly.
    """
    bt = jnp.asarray(block_tables, jnp.int32)
    spec = _chunk_row_axes(cfg)
    fields = {f: None for f in _POOLED_FIELDS}
    for field, (rpc, ax) in spec.items():
        pool = getattr(pcache, field)
        if pool is None:
            fields[field] = None
            continue
        g = pool[bt]                      # [B, C, ...page]
        row_axis = g.ndim + ax            # position of the rpc axis in g
        g = jnp.moveaxis(g, 1, row_axis - 1)
        shape = list(g.shape)
        shape[row_axis - 1:row_axis + 1] = [shape[row_axis - 1] * shape[row_axis]]
        fields[field] = g.reshape(shape)
    return GEARLayerCache(**fields, buf_k=pcache.buf_k, buf_v=pcache.buf_v,
                          length=pcache.length)


def gather_pool_chunks(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                       pages: jnp.ndarray) -> list[dict]:
    """Read pool pages into per-chunk payload dicts (batch-1 layout).

    The inverse of :func:`scatter_pool_chunks`: each payload field carries
    the ``[1, ...]`` batch axis :func:`splice_prefix_chunks` expects, so a
    prefix-cache hit gathers its pages straight into the batch-1 scaffold.
    """
    pages = jnp.asarray(pages, jnp.int32)
    n = pages.shape[0]
    spec = _chunk_row_axes(cfg)
    out = []
    for c in range(n):
        payload = {}
        for field in spec:
            pool = getattr(pcache, field)
            if pool is None:
                continue
            payload[field] = pool[pages[c]][None]       # [1, ...page]
        out.append(payload)
    return out


def scatter_pool_chunks(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                        pages: jnp.ndarray,
                        chunks: list[dict]) -> PagedGEARLayerCache:
    """Write per-chunk payload dicts (``extract_prefix_chunks`` layout,
    batch-1) into pool pages ``pages [len(chunks)]`` — the paged half of the
    slot-splice protocol: a batch-1 prefill's closed chunks become the
    slot's pages.  Out-of-range page ids drop the write.
    """
    if not chunks:
        return pcache
    pages = jnp.asarray(pages, jnp.int32)
    upd = {}
    for field in _chunk_row_axes(cfg):
        pool = getattr(pcache, field)
        if pool is None:
            continue
        vals = jnp.stack([ch[field][0] for ch in chunks], axis=0)
        upd[field] = pool.at[pages].set(vals.astype(pool.dtype), mode="drop")
    return dataclasses.replace(pcache, **upd)


def zero_pool_pages(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                    pages: jnp.ndarray) -> PagedGEARLayerCache:
    """Zero the given pool pages — run at admission on freshly allocated
    pages so exposed-but-unwritten block-table entries keep gathering the
    dense layout's zeros (the bit-parity invariant; DESIGN.md §5)."""
    pages = jnp.asarray(pages, jnp.int32)
    upd = {}
    for field in _chunk_row_axes(cfg):
        pool = getattr(pcache, field)
        if pool is None:
            continue
        zero = jnp.zeros((pages.shape[0],) + pool.shape[1:], pool.dtype)
        upd[field] = pool.at[pages].set(zero, mode="drop")
    return dataclasses.replace(pcache, **upd)


def append_token_paged(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                       block_tables: jnp.ndarray, k_t: jnp.ndarray,
                       v_t: jnp.ndarray, key: jax.Array | None = None):
    """Paged twin of :func:`append_token`: same buffer writes and the same
    slot-invariant compression event, but a closing chunk scatters into the
    slot's block-table page instead of dense batch rows.  Slots not at a
    chunk boundary (or past capacity) redirect the page index out of bounds
    and the scatter drops — one batched write serves every phase mix.
    """
    pol = cfg.policy
    nb = cfg.chunk
    if key is None:
        key = jax.random.PRNGKey(0)
    bt = jnp.asarray(block_tables, jnp.int32)
    buf_pos = pcache.length % nb
    buf_k = _slot_rows_update(pcache.buf_k, k_t[:, :, None, :], buf_pos)
    buf_v = _slot_rows_update(pcache.buf_v, v_t[:, :, None, :], buf_pos)
    pcache = dataclasses.replace(pcache, buf_k=buf_k, buf_v=buf_v,
                                 length=pcache.length + 1)

    def compress(c):
        need = (c.length % nb == 0) & (c.length > 0) & (c.length <= cfg.capacity)
        cidx = jnp.clip(jnp.maximum(c.length - 1, 0) // nb, 0, cfg.n_chunks - 1)
        P = c.k_packed.shape[0]
        page = jnp.take_along_axis(bt, cidx[:, None], axis=1)[:, 0]
        # page 0 is the reserved zero page: an idle slot (all-zero table
        # row) crossing a buffer boundary must drop its write rather than
        # corrupt the invariant every slot's out-of-extent reads depend on
        page = jnp.where(need & (page > 0), page, P)   # OOB -> scatter drops
        B, H, _, Dh = c.buf_k.shape
        kc = c.buf_k[:, :, None, :, :].astype(jnp.float32)
        vc = c.buf_v[:, :, None, :, :].astype(jnp.float32)
        # same slot-/step-invariant key as the dense path: a paged slot's
        # chunk is bit-identical to the dense slot's (splice isolation)
        comp = _compress_chunks(cfg, kc, vc, pol.rank_decode, key)
        upd = {}

        def put(field, vals):
            pool = getattr(c, field)
            upd[field] = pool.at[page].set(vals.astype(pool.dtype), mode="drop")

        put("k_packed", comp["k_packed"].reshape(B, H, nb, -1))
        put("v_packed", comp["v_packed"].reshape(B, H, nb, -1))
        for kv in ("k", "v"):
            put(f"{kv}_scale", _flatten_stat(cfg, comp[f"{kv}_scale"], kv))
            put(f"{kv}_zero", _flatten_stat(cfg, comp[f"{kv}_zero"], kv))
            if pol.use_lowrank:
                put(f"{kv}_a", comp[f"{kv}_a"].reshape(B, H, nb, pol.rank))
                put(f"{kv}_b", comp[f"{kv}_b"])
            if pol.use_sparse:
                sv, si = comp[f"{kv}_sp_val"], comp[f"{kv}_sp_idx"]
                if kv == "v" or cfg.k_scheme()[0] != "per_channel":
                    sv = sv.reshape(B, H, nb, sv.shape[-1])
                    si = si.reshape(B, H, nb, si.shape[-1])
                put(f"{kv}_sp_val", sv)
                put(f"{kv}_sp_idx", si)
        return dataclasses.replace(c, **upd)

    any_boundary = jnp.any((pcache.length % nb == 0) & (pcache.length > 0)
                           & (pcache.length <= cfg.capacity))
    return jax.lax.cond(any_boundary, compress, lambda c: c, pcache)


def attend_paged(cfg: CacheConfig, pcache: PagedGEARLayerCache,
                 block_tables: jnp.ndarray, q: jnp.ndarray, scale: float,
                 use_factored: bool = True) -> jnp.ndarray:
    """Portable paged decode attention: gather pages to the dense layout,
    then the standard factored :func:`attend` — identical values in
    identical shapes, so the result is bit-identical to the dense path.
    The fused twin (:func:`repro.kernels.ops.gear_attend_paged`) gathers by
    table index inside the kernel grid instead."""
    return attend(cfg, paged_to_dense(cfg, pcache, block_tables), q, scale,
                  use_factored=use_factored)


# ---------------------------------------------------------------------------
# Slot splicing (continuous batching)


def splice_slot(full, one, slot, axis: int = 0):
    """Write a batch-1 cache pytree ``one`` into batch row ``slot`` of ``full``.

    Works on any cache pytree whose leaves carry the batch dim at ``axis``
    (``axis=0`` for a single layer cache, ``axis=1`` for the engine's
    repeat-stacked ``[R, B, ...]`` trees — including RWKV/SSM states).
    ``slot`` may be a traced scalar, so one jitted program serves every slot.
    """
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(
        lambda f, o: jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=axis),
        full, one)


@functools.lru_cache(maxsize=64)
def _fresh_batch1_cached(cfg1: CacheConfig, dtype_name: str):
    return init_layer_cache(cfg1, jnp.dtype(dtype_name))


def fresh_batch1_cache(cfg: CacheConfig, dtype=jnp.bfloat16):
    """Memoized empty batch-1 cache for ``cfg``'s geometry.

    ``CacheConfig`` is hashable (frozen dataclasses all the way down), so
    the zero tree is built once per geometry instead of on every splice —
    :func:`reset_slot` / :func:`prefill_into_slot` sit on the continuous-
    batching per-request path and used to reallocate it each call.  The
    returned tree is shared: callers must treat it as read-only (splices
    copy out of it; never donate it into a jitted program).
    """
    cfg1 = cfg if cfg.batch == 1 else dataclasses.replace(cfg, batch=1)
    return _fresh_batch1_cached(cfg1, jnp.dtype(dtype).name)


def reset_slot(cfg: CacheConfig, cache, slot, dtype=jnp.bfloat16):
    """Return ``cache`` with batch row ``slot`` back in the empty state.

    Length goes to 0 (and window ``pos`` to -1), so every attend mask treats
    the slot as empty; stale K/V bytes are also zeroed for hygiene.
    """
    return splice_slot(cache, fresh_batch1_cache(cfg, dtype), slot)


def prefill_into_slot(cfg: CacheConfig, cache, k: jnp.ndarray, v: jnp.ndarray,
                      slot, key: jax.Array | None = None, dtype=jnp.bfloat16):
    """Prefill one request's K/V [1, H, n, Dh] into batch row ``slot``.

    The single-request cache is built exactly as a batch-1 prefill would
    build it (same chunking, same compression keys), then spliced over the
    slot — the cache-level half of the slot-splice protocol (DESIGN.md).
    The empty batch-1 scaffold comes from the :func:`fresh_batch1_cache`
    memo, so the per-request path allocates only the filled tree.
    """
    cfg1 = dataclasses.replace(cfg, batch=1)
    one = prefill_layer_cache(cfg1, fresh_batch1_cache(cfg1, dtype), k, v, key)
    return splice_slot(cache, one, slot)
