"""Per-vector outlier extraction (the sparse matrix ``S`` of GEAR, Eq. 4).

``Filter_s`` keeps the top ``s/2`` % and bottom ``s/2`` % magnitude-extreme
entries of each vector in full precision:

* K-cache orientation (``axis="token"``): vectors are **channels**; for each
  channel we filter along the token axis.
* V-cache orientation (``axis="channel"``): vectors are **tokens**; for each
  token we filter along the channel axis.

For a JIT-static representation, the fraction ``s`` maps to a fixed count
``k = ceil(s/2 · vec_len)`` per extreme, stored as (values, int32 indices)
pairs of capacity ``2k`` per vector.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

__all__ = ["SparseOutliers", "outlier_count", "filter_outliers",
           "filter_outliers_k", "densify", "iterative_topk"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["values", "indices"],
    meta_fields=["axis", "n", "d", "k"],
)
@dataclasses.dataclass(frozen=True)
class SparseOutliers:
    """Fixed-capacity sparse outlier set for a [..., n, d] tensor.

    axis="token":  values/indices are [..., d, 2k], indices in [0, n)
    axis="channel": values/indices are [..., n, 2k], indices in [0, d)
    """

    values: jnp.ndarray
    indices: jnp.ndarray
    axis: str
    n: int
    d: int
    k: int

    def size_bytes(self) -> int:
        # fp16 value + int32 index per kept entry (paper stores index vectors
        # in full precision; we use int32 which is what the table accounting
        # assumes for "2 index vectors + 1 value vector").
        return self.values.size * 2 + self.indices.size * 4


def outlier_count(vec_len: int, s: float) -> int:
    """Entries kept per extreme for sparsity fraction ``s`` (e.g. 0.02)."""
    return max(1, math.ceil(vec_len * s / 2.0))


def _scatter_last(shape, idx: jnp.ndarray, vals: jnp.ndarray, dtype) -> jnp.ndarray:
    """Scatter ``vals`` at ``idx`` along the last axis of a zeros(shape)."""
    lead = shape[:-1]
    length = shape[-1]
    flat_rows = 1
    for s in lead:
        flat_rows *= s
    k = idx.shape[-1]
    fidx = idx.reshape(flat_rows, k)
    fval = vals.reshape(flat_rows, k).astype(dtype)
    rows = jnp.arange(flat_rows, dtype=jnp.int32)[:, None]
    out = jnp.zeros((flat_rows, length), dtype=dtype)
    out = out.at[rows, fidx].set(fval)
    return out.reshape(shape)


def iterative_topk(x: jnp.ndarray, k: int, axis: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-``k`` of ``x`` along ``axis`` via ``k`` masked max sweeps.

    Returns (values, indices) with the reduced axis removed and ``k``
    appended last, in :func:`jax.lax.top_k` order (values descending, ties
    broken by lower index).  Built from vectorized max / compare-iota ops
    only, so the same routine runs inside Pallas TPU kernels (no gather or
    sort hardware needed) — the kernel-side twin of the ``lax.top_k`` call
    in :func:`filter_outliers_k`.
    """
    axis = axis % x.ndim
    work = x.astype(jnp.float32)
    n = x.shape[axis]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
    vals, idxs = [], []
    for _ in range(k):
        v = jnp.max(work, axis=axis)
        ve = jnp.expand_dims(v, axis)
        i = jnp.min(jnp.where(work == ve, iota, n), axis=axis)
        vals.append(v)
        idxs.append(i)
        work = jnp.where(iota == jnp.expand_dims(i, axis), -3.4e38, work)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def filter_outliers(x: jnp.ndarray, s: float, axis: str) -> tuple[SparseOutliers, jnp.ndarray]:
    """Split ``x`` [..., n, d] into (outliers S, remainder x - S).

    Returns the sparse set and the tensor with outlier positions zeroed,
    matching the paper's ``Quant(X - S)`` usage.  The fraction ``s`` maps to
    the fixed per-extreme count of :func:`outlier_count`;
    :func:`filter_outliers_k` is the count-level entry point shared with the
    fused compression kernel's oracle.
    """
    n, d = x.shape[-2], x.shape[-1]
    vec_len = n if axis == "token" else d
    return filter_outliers_k(x, outlier_count(vec_len, s), axis)


def filter_outliers_k(x: jnp.ndarray, k: int, axis: str) -> tuple[SparseOutliers, jnp.ndarray]:
    """:func:`filter_outliers` with the per-extreme count ``k`` given directly."""
    n, d = x.shape[-2], x.shape[-1]
    if axis == "token":
        xt = jnp.swapaxes(x, -1, -2)  # [..., d, n]
        vec_len = n
    elif axis == "channel":
        xt = x
        vec_len = d
    else:
        raise ValueError(f"axis must be 'token' or 'channel', got {axis!r}")
    if 2 * k > vec_len:
        raise ValueError(f"2k={2 * k} exceeds vector length {vec_len}")
    top_v, top_i = jax.lax.top_k(xt, k)
    bot_v_neg, bot_i = jax.lax.top_k(-xt, k)
    values = jnp.concatenate([top_v, -bot_v_neg], axis=-1)
    indices = jnp.concatenate([top_i, bot_i], axis=-1).astype(jnp.int32)
    dense_t = _scatter_last(xt.shape, indices, values, x.dtype)
    remainder_t = xt - dense_t
    if axis == "token":
        remainder = jnp.swapaxes(remainder_t, -1, -2)
    else:
        remainder = remainder_t
    sp = SparseOutliers(values=values, indices=indices, axis=axis, n=n, d=d, k=k)
    return sp, remainder


def densify(sp: SparseOutliers, dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct the dense [..., n, d] sparse matrix S."""
    if sp.axis == "token":
        lead = sp.values.shape[:-2]
        dense_t = _scatter_last(lead + (sp.d, sp.n), sp.indices, sp.values, dtype)
        return jnp.swapaxes(dense_t, -1, -2)
    lead = sp.values.shape[:-2]
    return _scatter_last(lead + (sp.n, sp.d), sp.indices, sp.values, dtype)
