"""Low-rank residual approximation via power iteration (paper Algorithm 2).

The SVDSolver of GEAR is the power-iteration scheme of PowerSGD (Vogels et
al., 2019): a handful of alternating ``A = X B`` / ``B = Xᵀ A`` steps with a
QR orthonormalization on the final sweep.  It returns factors ``A [n, r]``,
``B [d, r]`` with ``A Bᵀ`` close to the best rank-``r`` approximation, at a
fraction of the cost of a full SVD — the property that makes per-decode-chunk
low-rank extraction affordable.

All functions batch over leading dimensions, which is how the paper's
head-wise (and batch-wise) decomposition is realized: callers pass
``[B, H, n, d_head]`` and every head gets its own factors.

The same routine powers the distributed-training gradient compressor
(:mod:`repro.optim.grad_compress`), mirroring the PowerSGD lineage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["power_iteration", "lowrank_approx", "svd_topr", "apply_lowrank"]


def _batched_qr_q(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal basis of the columns of x (batched thin QR)."""
    q, _ = jnp.linalg.qr(x.astype(jnp.float32))
    return q


def power_iteration(
    x: jnp.ndarray,
    rank: int,
    iters: int = 4,
    key: jax.Array | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate top-``rank`` factors of ``x`` [..., n, d].

    Returns (A [..., n, rank], B [..., d, rank]) with ``A @ Bᵀ ≈ x_r``.
    Follows Algorithm 2: QR on B entering the final sweep, QR on A after the
    final ``A = X B``, then ``B = Xᵀ A`` carries the singular values.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n, d = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    xf = x.astype(jnp.float32)
    b = jax.random.normal(key, lead + (d, rank), dtype=jnp.float32)
    a = jnp.zeros(lead + (n, rank), dtype=jnp.float32)
    for l in range(iters):
        last = l == iters - 1
        if last:
            b = _batched_qr_q(b)
        a = jnp.einsum("...nd,...dr->...nr", xf, b)
        if last:
            a = _batched_qr_q(a)
        b = jnp.einsum("...nd,...nr->...dr", xf, a)
    return a, b


def lowrank_approx(x: jnp.ndarray, rank: int, iters: int = 4, key=None) -> jnp.ndarray:
    a, b = power_iteration(x, rank, iters, key)
    return apply_lowrank(a, b)


def apply_lowrank(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Materialize ``A @ Bᵀ`` (only used off the fast path / in tests)."""
    return jnp.einsum("...nr,...dr->...nd", a.astype(jnp.float32), b.astype(jnp.float32))


def svd_topr(x: jnp.ndarray, rank: int) -> jnp.ndarray:
    """Exact best rank-r approximation (oracle for tests/benchmarks)."""
    u, s, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    return jnp.einsum(
        "...nr,...r,...rd->...nd", u[..., :rank], s[..., :rank], vt[..., :rank, :]
    )
