"""Low-rank residual approximation via power iteration (paper Algorithm 2).

The SVDSolver of GEAR is the power-iteration scheme of PowerSGD (Vogels et
al., 2019): a handful of alternating ``A = X B`` / ``B = Xᵀ A`` steps with a
QR orthonormalization on the final sweep.  It returns factors ``A [n, r]``,
``B [d, r]`` with ``A Bᵀ`` close to the best rank-``r`` approximation, at a
fraction of the cost of a full SVD — the property that makes per-decode-chunk
low-rank extraction affordable.

All functions batch over leading dimensions, which is how the paper's
head-wise (and batch-wise) decomposition is realized: callers pass
``[B, H, n, d_head]`` and every head gets its own factors.

The same routine powers the distributed-training gradient compressor
(:mod:`repro.optim.grad_compress`), mirroring the PowerSGD lineage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["power_iteration", "lowrank_approx", "svd_topr", "apply_lowrank"]


def _batched_qr_q(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal basis of the columns of x (batched thin QR)."""
    q, _ = jnp.linalg.qr(x.astype(jnp.float32))
    return q


def _mgs_q(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal basis via modified Gram-Schmidt (pure einsums).

    Slightly weaker numerically than LAPACK QR, but free of custom calls:
    XLA's SPMD partitioner cannot handle LAPACK custom calls inside a
    partially-manual ``shard_map`` region (jaxlib 0.4.x aborts with
    ``IsManualSubgroup`` check failures), so the gradient compressor uses
    this path.  Ranks are small (<= 16); the unrolled loop is cheap.
    """
    xf = x.astype(jnp.float32)
    cols = []
    for j in range(xf.shape[-1]):
        v = xf[..., j]
        for q in cols:
            v = v - jnp.sum(q * v, axis=-1, keepdims=True) * q
        norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
        cols.append(v / jnp.maximum(norm, 1e-12))
    return jnp.stack(cols, axis=-1)


def power_iteration(
    x: jnp.ndarray,
    rank: int,
    iters: int = 4,
    key: jax.Array | None = None,
    orthonormalizer: str = "qr",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate top-``rank`` factors of ``x`` [..., n, d].

    Returns (A [..., n, rank], B [..., d, rank]) with ``A @ Bᵀ ≈ x_r``.
    Follows Algorithm 2: QR on B entering the final sweep, QR on A after the
    final ``A = X B``, then ``B = Xᵀ A`` carries the singular values.
    ``orthonormalizer="mgs"`` swaps LAPACK QR for Gram-Schmidt — required
    inside manual ``shard_map`` regions (see :func:`_mgs_q`).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    ortho = _mgs_q if orthonormalizer == "mgs" else _batched_qr_q
    n, d = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    xf = x.astype(jnp.float32)
    # The random init is drawn once at [d, rank] and broadcast over the
    # leading (batch/head/chunk) dims: each matrix's factors then depend only
    # on its own data and the key, never on its position in the batch.  The
    # serving cache relies on this batch-invariance so a request spliced into
    # a live batch compresses bit-identically to a solo run (DESIGN.md).
    b = jnp.broadcast_to(jax.random.normal(key, (d, rank), dtype=jnp.float32),
                         lead + (d, rank))
    a = jnp.zeros(lead + (n, rank), dtype=jnp.float32)
    for l in range(iters):
        last = l == iters - 1
        if last:
            b = ortho(b)
        a = jnp.einsum("...nd,...dr->...nr", xf, b)
        if last:
            a = ortho(a)
        b = jnp.einsum("...nd,...nr->...dr", xf, a)
    return a, b


def lowrank_approx(x: jnp.ndarray, rank: int, iters: int = 4, key=None) -> jnp.ndarray:
    a, b = power_iteration(x, rank, iters, key)
    return apply_lowrank(a, b)


def apply_lowrank(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Materialize ``A @ Bᵀ`` (only used off the fast path / in tests)."""
    return jnp.einsum("...nr,...dr->...nd", a.astype(jnp.float32), b.astype(jnp.float32))


def svd_topr(x: jnp.ndarray, rank: int) -> jnp.ndarray:
    """Exact best rank-r approximation (oracle for tests/benchmarks)."""
    u, s, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    return jnp.einsum(
        "...nr,...r,...rd->...nd", u[..., :rank], s[..., :rank], vt[..., :rank, :]
    )
