"""Compression policy configuration for the GEAR framework."""

from __future__ import annotations

import dataclasses

__all__ = ["CompressionPolicy", "FP16", "GEAR_DEFAULT", "named_policy"]


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Everything that defines how a KV cache is compressed.

    method:
      "fp16"          — no compression (baseline)
      "quant"         — backbone quantization only
      "outlier_quant" — quantization + sparse outliers (Table 8 baseline)
      "gear_l"        — quantization + low-rank residual (GEAR-L)
      "gear"          — quantization + low-rank + sparse (full GEAR)
    backbone:
      "kcvt"            — per-channel K / per-token V, coarse per-vector groups
      "kivi"            — per-channel K / per-token V, fine groups of ``group``
      "per_token_group" — FlexGen-style per-token grouping for both K and V
    """

    method: str = "gear"
    backbone: str = "kcvt"
    bits: int = 4
    group: int = 64          # fine-grained group size (kivi / per_token_group)
    rank: int = 4            # r_p: prefill rank
    rank_decode: int = 2     # r_g: per-decode-chunk rank
    sparsity: float = 0.02   # s
    power_iters: int = 4
    buffer_size: int = 64    # n_b streaming buffer / chunk size
    stat_dtype: str = "bfloat16"  # scale/zero storage dtype

    def __post_init__(self):
        if self.method not in ("fp16", "quant", "outlier_quant", "gear_l", "gear"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.backbone not in ("kcvt", "kivi", "per_token_group"):
            raise ValueError(f"unknown backbone {self.backbone!r}")
        if self.bits not in (2, 4, 8):
            raise ValueError(f"bits must be 2/4/8, got {self.bits}")
        if self.backbone in ("kivi", "per_token_group") and self.buffer_size % self.group:
            raise ValueError("buffer_size must be a multiple of group for fine-grained backbones")

    @property
    def use_lowrank(self) -> bool:
        return self.method in ("gear_l", "gear")

    @property
    def use_sparse(self) -> bool:
        return self.method in ("outlier_quant", "gear")

    @property
    def is_fp16(self) -> bool:
        return self.method == "fp16"

    def scheme_for(self, kind: str) -> tuple[str, int | None]:
        """(quant scheme, group) for tensor kind 'k' or 'v'."""
        if self.backbone == "per_token_group":
            return "per_token_group", self.group
        if kind == "k":
            return "per_channel", None if self.backbone == "kcvt" else self.group
        if kind == "v":
            return "per_token", None if self.backbone == "kcvt" else self.group
        raise ValueError(f"kind must be 'k' or 'v', got {kind!r}")


FP16 = CompressionPolicy(method="fp16")
# The paper's recommended settings: KCVT backbone at 4-bit, KIVI at 2-bit.
GEAR_DEFAULT = CompressionPolicy(method="gear", backbone="kcvt", bits=4)


def named_policy(name: str) -> CompressionPolicy:
    """Policies used throughout the paper's tables."""
    table = {
        "fp16": FP16,
        "per_token_q4": CompressionPolicy("quant", "per_token_group", bits=4),
        "per_token_q2": CompressionPolicy("quant", "per_token_group", bits=2),
        "kcvt4": CompressionPolicy("quant", "kcvt", bits=4),
        "kivi4": CompressionPolicy("quant", "kivi", bits=4),
        "kivi2": CompressionPolicy("quant", "kivi", bits=2),
        "outlier_kivi2": CompressionPolicy("outlier_quant", "kivi", bits=2),
        "gear_l_kcvt4": CompressionPolicy("gear_l", "kcvt", bits=4),
        "gear_kcvt4": CompressionPolicy("gear", "kcvt", bits=4),
        "gear_l_kivi2": CompressionPolicy("gear_l", "kivi", bits=2),
        "gear_kivi2": CompressionPolicy("gear", "kivi", bits=2),
    }
    if name not in table:
        raise KeyError(f"unknown policy {name!r}; options: {sorted(table)}")
    return table[name]
