"""Beyond-paper: adaptive low-rank budget allocation across heads.

The paper's §6.1 notes that a *uniform* rank per Key/Value matrix ignores
how unevenly residual energy is distributed across layers and heads, and
reports (without details) that adaptive allocation helps.  This module
implements it: given per-head quantization residuals, distribute a total
rank budget ``H·r_avg`` by greedy water-filling on the residual spectra —
each marginal rank unit goes to the head whose next singular value removes
the most energy.  Storage stays static-shaped (factors padded to
``max_rank`` columns with a rank mask), so the compressed cache layout is
unchanged; the *budget* (and hence the size accounting) matches uniform
rank exactly.

``adaptive_error_vs_uniform`` is the evaluation entry point used by
``benchmarks/bench_adaptive.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lowrank

__all__ = ["allocate_ranks", "adaptive_lowrank", "adaptive_error_vs_uniform"]


def _head_spectra(resid: jnp.ndarray, max_rank: int) -> jnp.ndarray:
    """Top-``max_rank`` singular values per head.  resid: [H, n, d] -> [H, max_rank]."""
    s = jnp.linalg.svd(resid.astype(jnp.float32), compute_uv=False)
    return s[..., :max_rank]


def allocate_ranks(spectra: jnp.ndarray, budget: int) -> jnp.ndarray:
    """Greedy water-filling.  spectra: [H, max_rank] singular values (desc).

    Returns int32 ranks [H] with sum == budget (≤ H·max_rank).  Marginal
    gain of the k-th rank unit on head h is σ_{h,k}² — allocating budget to
    the globally largest σ² is exactly the optimal assignment for Frobenius
    error under a total-rank constraint.
    """
    H, R = spectra.shape
    gains = jnp.square(spectra).reshape(-1)          # [H*R], head-major
    order = jnp.argsort(-gains)
    chosen = jnp.zeros((H * R,), bool).at[order[:budget]].set(True)
    return jnp.sum(chosen.reshape(H, R), axis=1).astype(jnp.int32)


def adaptive_lowrank(resid: jnp.ndarray, avg_rank: int, max_rank: int | None = None,
                     iters: int = 6, key=None):
    """Per-head factors under a shared budget.  resid: [H, n, d].

    Returns (A [H, n, max_rank], B [H, d, max_rank], ranks [H]); columns
    beyond each head's allocated rank are zeroed (A·Bᵀ uses only rank_h).
    """
    H, n, d = resid.shape
    max_rank = max_rank or min(4 * avg_rank, n, d)
    spectra = _head_spectra(resid, max_rank)
    ranks = allocate_ranks(spectra, budget=avg_rank * H)
    a, b = lowrank.power_iteration(resid, max_rank, iters=iters, key=key)
    mask = (jnp.arange(max_rank)[None, :] < ranks[:, None]).astype(a.dtype)
    return a * mask[:, None, :], b * mask[:, None, :], ranks


def adaptive_error_vs_uniform(resid: jnp.ndarray, rank: int, key=None) -> dict:
    """Relative Frobenius error: uniform rank-r vs adaptive at equal budget."""
    H, n, d = resid.shape
    base = jnp.linalg.norm(resid)
    a_u, b_u = lowrank.power_iteration(resid, rank, iters=6, key=key)
    err_u = jnp.linalg.norm(resid - lowrank.apply_lowrank(a_u, b_u)) / base
    a_a, b_a, ranks = adaptive_lowrank(resid, avg_rank=rank, key=key)
    err_a = jnp.linalg.norm(resid - lowrank.apply_lowrank(a_a, b_a)) / base
    return {"uniform": float(err_u), "adaptive": float(err_a),
            "ranks": [int(r) for r in ranks]}
