"""Bit-packing of low-precision integer codes into int32 carrier lanes.

TPU VMEM and the MXU operate natively on 32-bit lanes; packing 2/4/8-bit
quantization codes into int32 keeps loads dense (16/8/4 codes per lane) and
lets the Pallas kernels unpack with vectorized shifts+masks.  The same
layout is used by the pure-jnp reference path so the packed cache pytree is
identical regardless of which backend consumes it.

Layout: the **last axis** is packed.  For bit-width ``b`` and last-axis size
``D`` (must be divisible by ``32 // b``), codes ``x[..., i]`` with
``i = lane * per + j`` are stored in bits ``[j*b, (j+1)*b)`` of
``packed[..., lane]`` where ``per = 32 // b``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["codes_per_lane", "packed_width", "pack", "unpack"]


def codes_per_lane(bits: int) -> int:
    if bits not in (2, 4, 8):
        raise ValueError(f"unsupported bit-width {bits}; expected 2, 4 or 8")
    return 32 // bits


def packed_width(d: int, bits: int) -> int:
    per = codes_per_lane(bits)
    if d % per != 0:
        raise ValueError(f"last axis {d} not divisible by {per} ({bits}-bit)")
    return d // per


def pack(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unsigned integer codes in [0, 2**bits) along the last axis.

    codes: int32 array [..., D]  ->  int32 array [..., D // (32//bits)].
    """
    per = codes_per_lane(bits)
    d = codes.shape[-1]
    lanes = packed_width(d, bits)
    x = codes.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    x = x.reshape(codes.shape[:-1] + (lanes, per))
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[(None,) * (x.ndim - 1)]
    packed = jnp.sum(x << shifts, axis=-1, dtype=jnp.uint32)
    return packed.astype(jnp.int32)


def unpack(packed: jnp.ndarray, bits: int, d: int | None = None) -> jnp.ndarray:
    """Inverse of :func:`pack`.  Returns int32 codes [..., D]."""
    per = codes_per_lane(bits)
    lanes = packed.shape[-1]
    d_out = lanes * per if d is None else d
    x = packed.astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[(None,) * x.ndim]
    codes = (x[..., None] >> shifts) & jnp.uint32((1 << bits) - 1)
    codes = codes.reshape(packed.shape[:-1] + (lanes * per,))
    return codes[..., :d_out].astype(jnp.int32)
