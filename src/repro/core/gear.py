"""GEAR composition: X ≈ D̂ + L + S  (paper Section 3, Algorithm 1).

``compress_matrix`` implements one compression event over a tensor
``[..., n, d]`` (leading dims batch/heads — head-wise decomposition falls out
of batching).  Order follows Algorithm 1 exactly:

  1. S  = Filter_s(X)                        (outliers, if enabled)
  2. D̂  = Quant_b(X - S)                    (backbone)
  3. R  = X - deq(D̂) - S                    (quantization residual)
  4. L_h = SVDSolver_r(R_h) per head         (low-rank, if enabled)

Note the residual in step 3 uses the *dequantized* backbone — the paper's
``X − D̂ − S`` is only meaningful in reconstruction space, and reconstruction
is ``deq(D̂) + L + S``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import lowrank as lr
from repro.core import outlier as ol
from repro.core import quant as q
from repro.core.policy import CompressionPolicy

__all__ = ["CompressedMatrix", "compress_matrix", "decompress_matrix", "approx_error"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["qt", "sparse", "a", "b"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class CompressedMatrix:
    """GEAR-compressed stand-in for a [..., n, d] tensor.

    qt     : quantized backbone (always present)
    sparse : SparseOutliers or None
    a, b   : low-rank factors [..., n, r] / [..., d, r] or None
    """

    qt: q.QuantizedTensor
    sparse: ol.SparseOutliers | None
    a: jnp.ndarray | None
    b: jnp.ndarray | None

    def size_bytes(self) -> int:
        total = self.qt.size_bytes()
        if self.sparse is not None:
            total += self.sparse.size_bytes()
        if self.a is not None:
            total += self.a.size * 2 + self.b.size * 2
        return total


def compress_matrix(
    x: jnp.ndarray,
    policy: CompressionPolicy,
    kind: str,
    rank: int | None = None,
    key: jax.Array | None = None,
) -> CompressedMatrix:
    """Compress ``x`` [..., n, d] as the ``kind`` ('k' or 'v') cache tensor.

    ``rank`` overrides ``policy.rank`` (the engine passes ``rank_decode`` for
    streaming-buffer chunks).  Leading dims are treated as independent
    matrices, giving the paper's batch-wise/head-wise decomposition.
    """
    if policy.is_fp16:
        raise ValueError("fp16 policy has no compressed representation")
    scheme, group = policy.scheme_for(kind)
    axis = "token" if scheme == "per_channel" else "channel"

    sparse = None
    remainder = x
    if policy.use_sparse:
        sparse, remainder = ol.filter_outliers(x, policy.sparsity, axis)

    qt = q.quantize(remainder, policy.bits, scheme, group,
                    stat_dtype=jnp.dtype(policy.stat_dtype))

    a = b = None
    if policy.use_lowrank:
        r = policy.rank if rank is None else rank
        resid = x.astype(jnp.float32) - q.dequantize(qt)
        if sparse is not None:
            resid = resid - ol.densify(sparse)
        a, b = lr.power_iteration(resid, r, policy.power_iters, key)
        a = a.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    return CompressedMatrix(qt=qt, sparse=sparse, a=a, b=b)


def decompress_matrix(cm: CompressedMatrix, dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct deq(D̂) + L + S."""
    xh = q.dequantize(cm.qt)
    if cm.a is not None:
        xh = xh + lr.apply_lowrank(cm.a, cm.b)
    if cm.sparse is not None:
        xh = xh + ol.densify(cm.sparse)
    return xh.astype(dtype)


def approx_error(x: jnp.ndarray, policy: CompressionPolicy, kind: str = "k",
                 rank: int | None = None) -> jnp.ndarray:
    """Relative Frobenius approximation error of a policy on ``x``."""
    if policy.is_fp16:
        return jnp.zeros(())
    cm = compress_matrix(x, policy, kind, rank)
    xh = decompress_matrix(cm)
    xf = x.astype(jnp.float32)
    return jnp.linalg.norm(xf - xh) / jnp.maximum(jnp.linalg.norm(xf), 1e-8)
