"""Uniform asymmetric quantization backbones for KV caches.

Implements the three quantization schemes the paper builds on / compares
against, over tensors laid out ``[..., n, d]`` (n = tokens, d = channels):

* ``per_token_group`` — FlexGen-style: each token row split into contiguous
  groups of ``g`` channels; scale/zero per group.                      (2)
* ``per_channel``     — K-cache orientation (KIVI/KCVT): groups of ``g``
  tokens within one channel column.  ``g = n`` gives the coarse KCVT
  per-vector grouping; ``g = 64`` gives KIVI fine-grained grouping.
* ``per_token``       — V-cache orientation: groups of ``g`` channels within
  one token row.  ``g = d`` gives coarse KCVT; ``g = 64`` gives KIVI.

All schemes share the uniform quantizer of Eq. (2) of the paper:
``x̂ = round((x - min) / Δ)``, ``Δ = (max - min) / (2^b - 1)``, codes packed
into int32 lanes (:mod:`repro.core.packing`).  Dequantization restores
``x ≈ codes · Δ + min``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import packing

__all__ = [
    "QuantizedTensor",
    "quantize",
    "dequantize",
    "quant_error",
    "SCHEMES",
]

SCHEMES = ("per_token_group", "per_channel", "per_token")

_EPS = 1e-8


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["packed", "scale", "zero"],
    meta_fields=["bits", "scheme", "group", "n", "d"],
)
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Packed quantized tensor plus the metadata to invert it.

    packed : int32 [..., n, d // (32/bits)]
    scale  : f32/bf16 broadcastable group scales
    zero   : same shape as scale (the group minimum)
    """

    packed: jnp.ndarray
    scale: jnp.ndarray
    zero: jnp.ndarray
    bits: int
    scheme: str
    group: int
    n: int
    d: int

    @property
    def nbytes_packed(self) -> int:
        return self.packed.size * 4

    def size_bytes(self) -> int:
        """Total compressed bytes (codes + scales + zeros)."""
        return self.nbytes_packed + self.scale.size * 2 + self.zero.size * 2


def _group_minmax(x: jnp.ndarray, scheme: str, group: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (min, max) broadcast back to x's shape for the given scheme."""
    n, d = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    if scheme in ("per_token_group", "per_token"):
        if d % group != 0:
            raise ValueError(f"d={d} not divisible by group={group}")
        xg = x.reshape(lead + (n, d // group, group))
        mn = jnp.min(xg, axis=-1, keepdims=True)
        mx = jnp.max(xg, axis=-1, keepdims=True)
        return (
            jnp.broadcast_to(mn, xg.shape).reshape(x.shape),
            jnp.broadcast_to(mx, xg.shape).reshape(x.shape),
        )
    if scheme == "per_channel":
        if n % group != 0:
            raise ValueError(f"n={n} not divisible by group={group}")
        xg = x.reshape(lead + (n // group, group, d))
        mn = jnp.min(xg, axis=-2, keepdims=True)
        mx = jnp.max(xg, axis=-2, keepdims=True)
        return (
            jnp.broadcast_to(mn, xg.shape).reshape(x.shape),
            jnp.broadcast_to(mx, xg.shape).reshape(x.shape),
        )
    raise ValueError(f"unknown scheme {scheme!r}; expected one of {SCHEMES}")


def _compact_groups(full: jnp.ndarray, scheme: str, group: int) -> jnp.ndarray:
    """Collapse a broadcast per-entry stat down to one value per group."""
    n, d = full.shape[-2], full.shape[-1]
    lead = full.shape[:-2]
    if scheme in ("per_token_group", "per_token"):
        return full.reshape(lead + (n, d // group, group))[..., 0]
    return full.reshape(lead + (n // group, group, d))[..., 0, :]


def _expand_groups(compact: jnp.ndarray, scheme: str, group: int, n: int, d: int) -> jnp.ndarray:
    lead = compact.shape[: -2 if scheme == "per_channel" else -2]
    if scheme in ("per_token_group", "per_token"):
        x = jnp.repeat(compact[..., None], group, axis=-1)
        return x.reshape(lead + (n, d))
    x = jnp.repeat(compact[..., None, :], group, axis=-2)
    return x.reshape(lead + (n, d))


def quantize(
    x: jnp.ndarray,
    bits: int,
    scheme: str,
    group: int | None = None,
    stat_dtype: jnp.dtype = jnp.float32,
) -> QuantizedTensor:
    """Quantize ``x`` [..., n, d] with the given scheme.

    ``group=None`` selects the coarse per-vector grouping (KCVT): the whole
    channel column for ``per_channel``, the whole token row for ``per_token``.
    """
    n, d = x.shape[-2], x.shape[-1]
    if group is None:
        group = n if scheme == "per_channel" else d
    xf = x.astype(jnp.float32)
    mn_full, mx_full = _group_minmax(xf, scheme, group)
    scale_full = (mx_full - mn_full) / (2**bits - 1)
    scale_full = jnp.maximum(scale_full, _EPS)
    codes = jnp.clip(
        jnp.round((xf - mn_full) / scale_full), 0, 2**bits - 1
    ).astype(jnp.int32)
    packed = packing.pack(codes, bits)
    scale = _compact_groups(scale_full, scheme, group).astype(stat_dtype)
    zero = _compact_groups(mn_full, scheme, group).astype(stat_dtype)
    return QuantizedTensor(
        packed=packed, scale=scale, zero=zero,
        bits=bits, scheme=scheme, group=group, n=n, d=d,
    )


def dequantize(qt: QuantizedTensor, dtype: jnp.dtype = jnp.float32) -> jnp.ndarray:
    codes = packing.unpack(qt.packed, qt.bits, qt.d).astype(jnp.float32)
    scale = _expand_groups(qt.scale.astype(jnp.float32), qt.scheme, qt.group, qt.n, qt.d)
    zero = _expand_groups(qt.zero.astype(jnp.float32), qt.scheme, qt.group, qt.n, qt.d)
    return (codes * scale + zero).astype(dtype)


def quant_error(x: jnp.ndarray, bits: int, scheme: str, group: int | None = None) -> jnp.ndarray:
    """Frobenius-norm relative error of plain quantization (for benchmarks)."""
    qt = quantize(x, bits, scheme, group)
    xh = dequantize(qt)
    return jnp.linalg.norm(x - xh) / jnp.maximum(jnp.linalg.norm(x), _EPS)
