"""KV-cache size accounting (reproduces the paper's KV-size columns).

All sizes are *analytic* — derived from the storage layout, not measured —
which is exactly how the paper reports "KV size % of FP16" (Tables 1/2/9 and
Figure 6).  ``kv_size_fraction`` covers every method/backbone combination on
an ``n`` tokens × ``d`` channels cache (per layer; layers scale linearly).

Also home to the measured-error primitives (:func:`masked_rel_frobenius`,
:func:`masked_share`) shared by the offline parity tests and the online
fidelity probes (:mod:`repro.obs.fidelity`): masked Frobenius reductions
so a single jitted program covers any valid-token region.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.outlier import outlier_count
from repro.core.policy import CompressionPolicy

__all__ = ["SizeBreakdown", "kv_size_breakdown", "kv_size_fraction",
           "masked_rel_frobenius", "masked_share"]

_EPS = 1e-12


def masked_rel_frobenius(approx, ref, mask):
    """``||approx − ref||_F / ||ref||_F`` over ``mask`` (broadcastable
    boolean); jittable, any shapes."""
    m = jnp.asarray(mask, jnp.float32)
    a = jnp.asarray(approx, jnp.float32)
    r = jnp.asarray(ref, jnp.float32)
    num = jnp.sqrt(jnp.sum(((a - r) ** 2) * m))
    den = jnp.sqrt(jnp.sum((r ** 2) * m))
    return num / jnp.maximum(den, _EPS)


def masked_share(part, whole, mask):
    """``||part||_F / ||whole||_F`` over ``mask`` — the share a component
    (low-rank residual, sparse outliers) contributes to a reconstruction."""
    m = jnp.asarray(mask, jnp.float32)
    p = jnp.asarray(part, jnp.float32)
    w = jnp.asarray(whole, jnp.float32)
    num = jnp.sqrt(jnp.sum((p ** 2) * m))
    den = jnp.sqrt(jnp.sum((w ** 2) * m))
    return num / jnp.maximum(den, _EPS)

FP16_BYTES = 2
IDX_BYTES = 4
STAT_BYTES = 2  # scale/zero stored bf16


@dataclasses.dataclass
class SizeBreakdown:
    quant_bytes: float = 0.0
    stat_bytes: float = 0.0      # scales + zeros
    buffer_bytes: float = 0.0    # fp16 streaming buffer / residual tokens
    lowrank_bytes: float = 0.0
    sparse_bytes: float = 0.0
    fp16_bytes: float = 0.0

    @property
    def total(self) -> float:
        return (self.quant_bytes + self.stat_bytes + self.buffer_bytes
                + self.lowrank_bytes + self.sparse_bytes + self.fp16_bytes)


def _ngroups(policy: CompressionPolicy, kind: str, n: int, d: int) -> float:
    scheme, group = policy.scheme_for(kind)
    if scheme == "per_token_group":
        return n * (d / group)
    if scheme == "per_channel":
        g = n if group is None else group
        return math.ceil(n / g) * d
    g = d if group is None else group
    return n * (d / g)


def kv_size_breakdown(
    policy: CompressionPolicy,
    n: int,
    d: int,
    num_heads: int = 1,
    head_dim: int | None = None,
    per_chunk_lowrank: bool = False,
    idealized_sparse: bool = True,
) -> SizeBreakdown:
    """Bytes to store one K *or* V matrix of n tokens × d channels.

    ``num_heads``/``head_dim`` control the head-wise low-rank factor count
    (paper stores A [n, r], B [d_H, r] per head).  ``per_chunk_lowrank``
    accounts the serving engine's chunked variant instead of the paper's
    whole-prefill variant.
    """
    bd = SizeBreakdown()
    if policy.is_fp16:
        bd.fp16_bytes = n * d * FP16_BYTES
        return bd
    if head_dim is None:
        head_dim = d // num_heads

    # Streaming buffer: residual tokens kept fp16.  KIVI-style fine grouping
    # requires the buffer to hold up to a full group; coarse KCVT lets it be
    # small.  On average half the buffer is occupied; the paper accounts the
    # full allocation, so we do too.
    nb = policy.buffer_size
    compressed_n = (n // nb) * nb if per_chunk_lowrank else max(0, n - n % nb)
    bd.buffer_bytes = nb * d * FP16_BYTES

    bd.quant_bytes = compressed_n * d * policy.bits / 8.0
    bd.stat_bytes = 2 * STAT_BYTES * _ngroups(policy, "k", compressed_n, d)

    if policy.use_lowrank:
        r = policy.rank
        if per_chunk_lowrank:
            nchunks = compressed_n // nb
            r_g = policy.rank_decode
            bd.lowrank_bytes = num_heads * nchunks * (nb * r_g + head_dim * r_g) * FP16_BYTES
        else:
            bd.lowrank_bytes = num_heads * (compressed_n * r + head_dim * r) * FP16_BYTES

    if policy.use_sparse and idealized_sparse:
        # Paper-style accounting: exactly s·n·d entries.  Index stored as
        # uint8 (chunk-relative position fits one byte — a storage
        # optimization over the paper's full-precision index vectors).
        bd.sparse_bytes = policy.sparsity * compressed_n * d * (FP16_BYTES + 1)
    elif policy.use_sparse:
        # per-vector fixed capacity 2k entries (value fp16 + uint8 index)
        k = outlier_count(compressed_n if policy.scheme_for("k")[0] == "per_channel" else d,
                          policy.sparsity)
        nvec = d if policy.scheme_for("k")[0] == "per_channel" else compressed_n
        bd.sparse_bytes = nvec * 2 * k * (FP16_BYTES + 1)
    return bd


def kv_size_fraction(policy: CompressionPolicy, n: int, d: int,
                     num_heads: int = 1, head_dim: int | None = None,
                     per_chunk_lowrank: bool = False,
                     idealized_sparse: bool = True) -> float:
    """Compressed size as a fraction of the FP16 cache (paper's 'KV size')."""
    bd = kv_size_breakdown(policy, n, d, num_heads, head_dim, per_chunk_lowrank,
                           idealized_sparse)
    return bd.total / (n * d * FP16_BYTES)
