#!/usr/bin/env python
"""Docs-consistency gate: docs/serving.md must document every EngineConfig
knob.

Parses the ``EngineConfig`` dataclass out of ``src/repro/serving/engine.py``
with ``ast`` (no imports — the lint lane has no jax) and asserts each field
name appears as an inline-code knob (`` `name` ``) in docs/serving.md, so
adding a knob without documenting it fails CI.  Run from the repo root:

    python scripts/check_docs.py
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
ENGINE = ROOT / "src" / "repro" / "serving" / "engine.py"
DOC = ROOT / "docs" / "serving.md"


def engine_config_fields() -> list[str]:
    tree = ast.parse(ENGINE.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineConfig":
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    raise SystemExit(f"EngineConfig dataclass not found in {ENGINE}")


def main() -> int:
    fields = engine_config_fields()
    if not fields:
        print(f"error: EngineConfig in {ENGINE} has no annotated fields")
        return 1
    doc = DOC.read_text() if DOC.exists() else ""
    if not doc:
        print(f"error: {DOC} is missing or empty")
        return 1
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", doc))
    missing = [f for f in fields if f not in documented]
    if missing:
        print(f"error: docs/serving.md does not document these EngineConfig "
              f"knobs: {', '.join(missing)}")
        print("add a row to the knob reference in docs/serving.md §1")
        return 1
    print(f"docs/serving.md documents all {len(fields)} EngineConfig knobs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
