#!/usr/bin/env python
"""Docs-consistency gate.

Two checks, both ast-based (no imports — the lint lane has no jax):

1. docs/serving.md must document every ``EngineConfig`` knob: the
   dataclass is parsed out of ``src/repro/serving/engine.py`` and each
   field name must appear as an inline-code knob (`` `name` ``).
2. docs/observability.md must document every metric in the telemetry
   catalog: every ``MetricSpec(name=...)`` literal in
   ``src/repro/obs/catalog.py`` must appear as inline code, so adding a
   metric without documenting it fails CI.

Run from the repo root:

    python scripts/check_docs.py
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
ENGINE = ROOT / "src" / "repro" / "serving" / "engine.py"
CATALOG = ROOT / "src" / "repro" / "obs" / "catalog.py"
SERVING_DOC = ROOT / "docs" / "serving.md"
OBS_DOC = ROOT / "docs" / "observability.md"

_CODE = re.compile(r"`([A-Za-z_][A-Za-z0-9_]*)`")


def engine_config_fields() -> list[str]:
    tree = ast.parse(ENGINE.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineConfig":
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    raise SystemExit(f"EngineConfig dataclass not found in {ENGINE}")


def catalog_metric_names() -> list[str]:
    """Every metric name declared in the obs catalog's METRICS tuple.

    A metric is a ``MetricSpec(...)`` call whose first positional (or
    ``name=``) argument is a string literal; parsing the literals keeps
    this lint-lane safe (catalog.py imports nothing heavier than stdlib,
    but the gate should not depend on that staying true).
    """
    names: list[str] = []
    for node in ast.walk(ast.parse(CATALOG.read_text())):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "MetricSpec"):
            continue
        arg: ast.expr | None = node.args[0] if node.args else None
        if arg is None:
            for kw in node.keywords:
                if kw.arg == "name":
                    arg = kw.value
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            names.append(arg.value)
    if not names:
        raise SystemExit(f"no MetricSpec names found in {CATALOG}")
    return names


def documented_names(doc_path: pathlib.Path) -> set[str]:
    if not doc_path.exists() or not doc_path.read_text():
        raise SystemExit(f"error: {doc_path} is missing or empty")
    return set(_CODE.findall(doc_path.read_text()))


def main() -> int:
    rc = 0

    fields = engine_config_fields()
    missing = [f for f in fields if f not in documented_names(SERVING_DOC)]
    if missing:
        print(f"error: docs/serving.md does not document these EngineConfig "
              f"knobs: {', '.join(missing)}")
        print("add a row to the knob reference in docs/serving.md §1")
        rc = 1
    else:
        print(f"docs/serving.md documents all {len(fields)} EngineConfig knobs")

    metrics = catalog_metric_names()
    missing = [m for m in metrics if m not in documented_names(OBS_DOC)]
    if missing:
        print(f"error: docs/observability.md does not document these catalog "
              f"metrics: {', '.join(missing)}")
        print("add a row to the metric catalog tables in docs/observability.md")
        rc = 1
    else:
        print(f"docs/observability.md documents all {len(metrics)} "
              f"catalog metrics")

    return rc


if __name__ == "__main__":
    sys.exit(main())
