#!/usr/bin/env python3
"""CI schema check for the serving telemetry exports (stdlib-only).

Validates the two artifacts ``repro.launch.serve --metrics-json /
--trace-out`` writes (and that ``Observability.write_metrics_json`` /
``write_trace`` produce):

* a **metrics snapshot** — schema tag ``gear-repro/metrics/v1``; every
  metric carries ``name`` / ``type`` / ``help`` / ``labels`` / ``series``;
  counter and gauge series are ``{labels, value}``; histogram series carry
  monotone non-decreasing cumulative ``buckets`` ending at ``+Inf``, with
  the ``+Inf`` count equal to ``count``; every series' label keys equal the
  metric's declared label names;
* a **Chrome trace** — schema tag ``gear-repro/trace/v1``; every event is
  a complete-phase (``ph: X``, with ``dur >= 0``) or instant (``ph: i``)
  record with ``name`` / ``ts`` / ``tid``; every ``tid`` (one per request)
  has exactly one ``request`` event whose args carry a terminal status.

Run from CI after the serve smoke::

    python -m repro.launch.serve --smoke --obs \
        --metrics-json out/metrics.json --trace-out out/trace.json
    python scripts/check_obs_export.py out/metrics.json out/trace.json

Exit status: 0 valid, 1 with every violation listed on stderr.
"""

from __future__ import annotations

import json
import sys

METRICS_SCHEMA = "gear-repro/metrics/v1"
TRACE_SCHEMA = "gear-repro/trace/v1"


def check_metrics(doc) -> list[str]:
    errs = []
    if doc.get("schema") != METRICS_SCHEMA:
        errs.append(f"metrics: schema {doc.get('schema')!r} != {METRICS_SCHEMA!r}")
    if not isinstance(doc.get("time"), (int, float)):
        errs.append("metrics: missing numeric 'time'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, list) or not metrics:
        return errs + ["metrics: empty or missing 'metrics' list"]
    seen = set()
    for m in metrics:
        name = m.get("name", "<unnamed>")
        if name in seen:
            errs.append(f"metrics: duplicate metric {name!r}")
        seen.add(name)
        kind = m.get("type")
        if kind not in ("counter", "gauge", "histogram"):
            errs.append(f"{name}: bad type {kind!r}")
            continue
        if not m.get("help"):
            errs.append(f"{name}: missing help text")
        labels = m.get("labels")
        if not isinstance(labels, list):
            errs.append(f"{name}: missing label-name list")
            continue
        for s in m.get("series", []):
            if set(s.get("labels", {})) != set(labels):
                errs.append(f"{name}: series labels {sorted(s.get('labels', {}))}"
                            f" != declared {sorted(labels)}")
            if kind == "histogram":
                errs.extend(_check_hist_series(name, s))
            elif not isinstance(s.get("value"), (int, float)):
                errs.append(f"{name}: series without numeric value")
    return errs


def _check_hist_series(name: str, s: dict) -> list[str]:
    errs = []
    buckets = s.get("buckets")
    if not isinstance(buckets, list) or not buckets:
        return [f"{name}: histogram series without buckets"]
    if buckets[-1].get("le") != "+Inf":
        errs.append(f"{name}: last bucket le={buckets[-1].get('le')!r}, "
                    "want '+Inf'")
    counts = [b.get("count") for b in buckets]
    if any(not isinstance(c, (int, float)) or c < 0 for c in counts):
        errs.append(f"{name}: non-numeric/negative bucket count")
    elif any(a > b for a, b in zip(counts, counts[1:])):
        errs.append(f"{name}: cumulative bucket counts decrease: {counts}")
    if isinstance(s.get("count"), (int, float)) and counts:
        if counts[-1] != s["count"]:
            errs.append(f"{name}: +Inf bucket {counts[-1]} != count {s['count']}")
    else:
        errs.append(f"{name}: histogram series without numeric count")
    if not isinstance(s.get("sum"), (int, float)):
        errs.append(f"{name}: histogram series without numeric sum")
    return errs


def check_trace(doc) -> list[str]:
    errs = []
    if doc.get("schema") != TRACE_SCHEMA:
        errs.append(f"trace: schema {doc.get('schema')!r} != {TRACE_SCHEMA!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return errs + ["trace: empty or missing 'traceEvents'"]
    requests: dict = {}
    for e in events:
        where = f"trace event {e.get('name', '<unnamed>')!r}"
        if not e.get("name"):
            errs.append("trace: event without a name")
        if e.get("ph") not in ("X", "i"):
            errs.append(f"{where}: ph {e.get('ph')!r} not in ('X', 'i')")
        if not isinstance(e.get("ts"), (int, float)):
            errs.append(f"{where}: missing numeric ts")
        if "tid" not in e:
            errs.append(f"{where}: missing tid")
        if e.get("ph") == "X" and not (isinstance(e.get("dur"), (int, float))
                                       and e["dur"] >= 0):
            errs.append(f"{where}: complete event without dur >= 0")
        if e.get("name") == "request":
            requests.setdefault(e.get("tid"), []).append(e)
    if not requests:
        errs.append("trace: no per-request 'request' events")
    for tid, evs in sorted(requests.items()):
        if len(evs) != 1:
            errs.append(f"trace: tid {tid}: {len(evs)} request events (want 1)")
        if not evs[0].get("args", {}).get("status"):
            errs.append(f"trace: tid {tid}: request event without a status")
    return errs


def main(argv) -> int:
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 1
    metrics_path, trace_path = argv
    errs = []
    for path, checker, tag in ((metrics_path, check_metrics, "metrics"),
                               (trace_path, check_trace, "trace")):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errs.append(f"{tag}: cannot load {path!r}: {e}")
            continue
        errs.extend(checker(doc))
    for e in errs:
        print(f"FAIL {e}", file=sys.stderr)
    if errs:
        print(f"check_obs_export: {len(errs)} violation(s)", file=sys.stderr)
        return 1
    print(f"check_obs_export: {metrics_path} and {trace_path} schema-valid")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
