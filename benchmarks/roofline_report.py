"""Aggregate dry-run JSONs into the §Roofline table (markdown + CSV)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "experiments", "dryrun")


def load_records(mesh: str = "16x16") -> list[dict]:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json")))
    if not files:
        raise FileNotFoundError(f"no dry-run records in {DRYRUN_DIR}")
    out = []
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if "roofline" in rec:
            out.append(rec)
    return out


def markdown_table(records: list[dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "bottleneck | MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['bottleneck']} | {rl['flops_eff']:.2f} | "
            f"{rl['roofline_frac']:.3f} |")
    return hdr + "\n".join(rows)


def run(emit_csv: bool = False, mesh: str = "16x16"):
    records = load_records(mesh)
    if emit_csv:
        for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
            rl = r["roofline"]
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                 f"bottleneck={rl['bottleneck']} "
                 f"compute={rl['compute_s']:.3e}s memory={rl['memory_s']:.3e}s "
                 f"coll={rl['collective_s']:.3e}s frac={rl['roofline_frac']:.3f}")
    return records


if __name__ == "__main__":
    print(markdown_table(load_records()))
