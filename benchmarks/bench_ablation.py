"""Paper Fig 4: ablations on sparsity s, rank r, error-reduction token
fraction p, and the error-vs-size tradeoff sweep (Fig 4c)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, kv_like
from repro.core import gear, lowrank, metrics, quant
from repro.core.policy import CompressionPolicy, named_policy


def fig4a_sensitivity(key):
    # Real KV residuals are dominated by coherent token structure (paper
    # Fig 2b); bias the synthetic tensor accordingly: strong shared low-rank
    # component, mild outliers.
    x = kv_like(key, (1, 4, 1024, 128), outlier_p=0.003, outlier_scale=5.0,
                corr_rank=8)
    x = x + 2.0 * kv_like(jax.random.fold_in(key, 9), (1, 4, 1024, 128),
                          outlier_p=0.0, corr_rank=4)
    base = named_policy("gear_kivi2")
    # vary sparsity at r=4
    for s in (0.0, 0.01, 0.02, 0.05):
        pol = dataclasses.replace(base, sparsity=max(s, 1e-9),
                                  method="gear" if s > 0 else "gear_l")
        err = float(gear.approx_error(x, pol, "k"))
        emit(f"fig4a_sparsity/s={s}", 0.0, f"rel_err={err:.4f}")
    # vary rank at s=2%
    errs = {}
    for r in (0, 2, 4, 8):
        pol = dataclasses.replace(base, rank=max(r, 1),
                                  method="gear" if r > 0 else "outlier_quant")
        errs[r] = float(gear.approx_error(x, pol, "k"))
        emit(f"fig4a_rank/r={r}", 0.0, f"rel_err={errs[r]:.4f}")
    # dropping low-rank hurts much more than dropping sparse (paper finding)
    e_full = errs[4]
    e_norank = errs[0]
    pol_nosparse = dataclasses.replace(base, method="gear_l")
    e_nosparse = float(gear.approx_error(x, pol_nosparse, "k"))
    emit("fig4a_component_importance", 0.0,
         f"full={e_full:.4f} no_lowrank={e_norank:.4f} no_sparse={e_nosparse:.4f}")
    # Robust claim: both components help, together they're best.  (Which
    # single ablation hurts more flips with the data's outlier mass — the
    # paper's own Table 8 shows the same flip across models/datasets.)
    assert e_full < min(e_norank, e_nosparse)
    assert max(e_norank, e_nosparse) < 1.5 * min(e_norank, e_nosparse)
    return errs


def fig4b_token_fraction(key):
    """Apply low-rank error reduction to only the last p% of tokens."""
    x = kv_like(key, (1, 4, 1024, 128))
    pol = named_policy("kivi2")
    scheme, group = pol.scheme_for("k")
    qt = quant.quantize(x, pol.bits, scheme, group)
    resid = x - quant.dequantize(qt)
    n = x.shape[-2]
    base = float(jnp.linalg.norm(x))
    for p in (0.0, 0.25, 0.5, 1.0):
        keep = int(n * p)
        r_part = resid[..., n - keep:, :] if keep else None
        err_tail = resid
        if keep:
            a, b = lowrank.power_iteration(r_part, 4, 4)
            fixed = r_part - lowrank.apply_lowrank(a, b)
            err_tail = jnp.concatenate([resid[..., : n - keep, :], fixed], axis=-2)
        err = float(jnp.linalg.norm(err_tail)) / base
        emit(f"fig4b_token_fraction/p={p}", 0.0, f"rel_err={err:.4f}")


def fig4c_size_sweep(key):
    """Error vs KV-size fraction across methods and bit-widths."""
    x = kv_like(key, (1, 4, 1024, 128))
    n, d = 1024, 128
    rows = []
    for name in ("per_token_q2", "per_token_q4", "kivi2", "kivi4",
                 "gear_l_kivi2", "gear_kivi2", "gear_l_kcvt4", "gear_kcvt4"):
        pol = named_policy(name)
        err = float(gear.approx_error(x, pol, "k"))
        frac = metrics.kv_size_fraction(pol, n, d, num_heads=1, head_dim=d)
        rows.append((name, frac, err))
        emit(f"fig4c_sweep/{name}", 0.0, f"kv_frac={frac:.3f} rel_err={err:.4f}")
    # at comparable size, GEAR variants dominate plain quant
    by = dict((r[0], r) for r in rows)
    assert by["gear_kivi2"][2] < by["kivi2"][2]
    assert by["gear_l_kivi2"][2] < by["kivi2"][2]
    return rows


def run(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    fig4a_sensitivity(key)
    fig4b_token_fraction(key)
    fig4c_size_sweep(key)


if __name__ == "__main__":
    run()
