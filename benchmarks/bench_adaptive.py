"""Beyond-paper bench: adaptive per-head rank allocation (paper §6.1
future work) vs the paper's uniform rank, at equal total budget."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, kv_like
from repro.core import quant
from repro.core.adaptive import adaptive_error_vs_uniform
from repro.core.policy import named_policy


def _heterogeneous_residual(key, H=8, n=512, d=128):
    """Residuals with very uneven energy across heads (real caches are)."""
    x = kv_like(key, (H, n, d))[...]
    # scale heads by a steep profile so rank demand differs
    head_scale = jnp.logspace(0, 1.2, H)[:, None, None]
    x = x * head_scale
    pol = named_policy("kivi2")
    qt = quant.quantize(x, pol.bits, *pol.scheme_for("k"))
    return x - quant.dequantize(qt)


def run(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    resid = _heterogeneous_residual(key)
    for r in (2, 4, 8):
        res = adaptive_error_vs_uniform(resid, rank=r, key=key)
        gain = (res["uniform"] - res["adaptive"]) / res["uniform"] * 100
        emit(f"beyond_adaptive_rank/r={r}", 0.0,
             f"uniform={res['uniform']:.4f} adaptive={res['adaptive']:.4f} "
             f"gain={gain:.1f}% ranks={res['ranks']}")
        assert res["adaptive"] <= res["uniform"] + 1e-6
    return res


if __name__ == "__main__":
    run()
