"""Paper Table 6 / Table 7 / Fig 3b: peak memory, max batch, max seq len.

The paper's inference setting: LLaMA2-7B, 8-bit weights, V100-16GB, input
1000 + generate 500, FlashAttention, requests prefilled one-at-a-time then
batch-decoded (so prefill workspace does not scale with batch):

  peak(B, n) = weights(8bit) + base + act_prefill + B · KV_policy(n)

with two constants calibrated once on the paper's FP16 rows and reused for
every GEAR prediction: ``act_prefill ≈ 1.5 GB`` (Table 6 FP16 batch-1 row)
and ``ACT_PER_TOKEN ≈ 1.0 MB`` (Table 7 FP16 max-seq row, used for the
seq-scaling variant where prefill workspace grows with n).  KV fractions
come from the layout accounting validated against Table 9 — so every GEAR
number below is a prediction, not a fit.  PyTorch-allocator effects put
±15-25 % noise on the paper's own measurements; asserts are set accordingly.
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import metrics
from repro.core.policy import FP16, named_policy

GB = 1024**3
USABLE = 11.3 * GB             # V100 16GB minus CUDA/allocator floor (calibrated)
N_IN, N_GEN = 1000, 500
ACT_PREFILL = 1.5 * GB         # single-request prefill workspace (calibrated)
ACT_PER_TOKEN = 1.0 * 1024**2  # prefill workspace per token (Table 7 calibration)
BASE = 0.2 * GB


def kv_bytes_per_seq(policy, cfg, n):
    d = cfg.num_kv_heads * cfg.head_dim
    frac = 1.0 if policy.is_fp16 else metrics.kv_size_fraction(
        policy, n, d, num_heads=cfg.num_kv_heads, head_dim=cfg.head_dim)
    return 2 * cfg.num_layers * n * d * 2 * frac  # K and V


def peak_mem(policy, cfg, batch, n=N_IN + N_GEN):
    weights = cfg.param_count() * 1  # 8-bit weights
    return weights + BASE + ACT_PREFILL + batch * kv_bytes_per_seq(policy, cfg, n)


def max_batch(policy, cfg, budget=USABLE):
    b = 1
    while peak_mem(policy, cfg, b + 1) <= budget:
        b += 1
    return b


def max_seq_len(policy, cfg, budget=15 * GB, batch=1):
    """Table 7 variant: prefill workspace grows with n (streaming GEAR
    compression keeps the cache at the policy fraction throughout)."""
    weights = cfg.param_count() * 1
    lo, hi = 256, 1 << 21
    while hi - lo > 16:
        mid = (lo + hi) // 2
        use = weights + BASE + batch * (ACT_PER_TOKEN * mid
                                        + kv_bytes_per_seq(policy, cfg, mid))
        if use <= budget:
            lo = mid
        else:
            hi = mid
    return lo


def run():
    cfg = get_config("llama2-7b")
    pol2 = dataclasses.replace(named_policy("gear_kivi2"), buffer_size=64)

    for b, paper in ((1, 8.44), (3, 11.44)):
        ours = peak_mem(FP16, cfg, b) / GB
        emit(f"table6_peak_mem/fp16_b{b}", 0.0, f"ours={ours:.2f}GB paper={paper}GB")
        assert abs(ours - paper) / paper < 0.25
    for b, paper in ((1, 7.31), (8, 10.53), (18, 14.63)):
        ours = peak_mem(pol2, cfg, b) / GB
        emit(f"table6_peak_mem/gear2_b{b}", 0.0, f"ours={ours:.2f}GB paper={paper}GB")
        assert abs(ours - paper) / paper < 0.3

    mb_fp16 = max_batch(FP16, cfg)
    mb_gear = max_batch(pol2, cfg)
    emit("table6_max_batch/fp16", 0.0, f"ours={mb_fp16} paper=3")
    emit("table6_max_batch/gear2", 0.0, f"ours={mb_gear} paper=18")
    ratio = peak_mem(FP16, cfg, mb_gear) / peak_mem(pol2, cfg, mb_gear)
    emit("fig3b_peak_reduction", 0.0,
         f"mem_ratio_at_b{mb_gear}={ratio:.2f}x paper=2.39x")

    ms_fp16 = max_seq_len(FP16, cfg)
    ms_gear = max_seq_len(pol2, cfg)
    emit("table7_max_seqlen/fp16", 0.0, f"ours={ms_fp16} paper=5319")
    emit("table7_max_seqlen/gear2", 0.0, f"ours={ms_gear} paper=7291")
    assert abs(ms_fp16 - 5319) / 5319 < 0.25
    assert abs(ms_gear - 7291) / 7291 < 0.25

    kv_ratio = kv_bytes_per_seq(FP16, cfg, N_IN + N_GEN) / kv_bytes_per_seq(pol2, cfg, N_IN + N_GEN)
    emit("kv_bytes_ratio/gear2_vs_fp16", 0.0, f"{kv_ratio:.2f}x")
    return {"max_batch": (mb_fp16, mb_gear), "max_seq": (ms_fp16, ms_gear)}


if __name__ == "__main__":
    run()
