"""Shared benchmark utilities: realistic KV tensors, timing, CSV/JSON rows."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str, value: float | None = None) -> None:
    """Record one bench row.  ``value`` is an optional machine-readable
    metric (tok/s, bytes, ratio) the CI regression gate
    (benchmarks/check_regression.py) can diff against baseline.json —
    ``derived`` stays the human-readable summary string."""
    ROWS.append((name, us_per_call, derived, value))
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str) -> None:
    """Dump every row emitted so far as a JSON list (CI bench artifacts)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    rows = [{"name": n, "us_per_call": t, "derived": der,
             **({"value": val} if val is not None else {})}
            for n, t, der, val in ROWS]
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)


def kv_like(key, shape=(1, 8, 1024, 128), outlier_p=0.005, outlier_scale=8.0,
            corr_rank=16):
    """Heavy-tailed token-correlated tensors mimicking real KV statistics:
    per-channel structure (a few large-magnitude channels, as observed by
    KIVI/KVQuant) + shared low-rank token structure + outliers."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    *lead, n, d = shape
    base = jax.random.normal(k1, shape)
    u = jax.random.normal(k2, tuple(lead) + (n, corr_rank))
    v = jax.random.normal(k3, tuple(lead) + (corr_rank, d))
    chan_scale = 1.0 + 4.0 * jax.random.bernoulli(k4, 0.03, tuple(lead) + (1, d))
    x = (base + 1.2 * u @ v / corr_rank**0.5) * chan_scale
    mask = jax.random.bernoulli(k5, outlier_p, shape)
    return x * (1 + outlier_scale * mask)


def timeit(fn, *args, iters=3, warmup=1) -> float:
    """Median wall time in microseconds (CPU; relative numbers only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
