"""Paper Tables 1/2/9 KV-size columns + Fig 6 component breakdown."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import metrics
from repro.core.policy import named_policy

# (policy, paper n_b, paper table value) for the GSM8k-CoT shape (Table 9 Ave.)
TABLE9 = [
    ("per_token_q4", 64, 0.342), ("kcvt4", 20, 0.271), ("kivi4", 64, 0.342),
    ("gear_l_kcvt4", 20, 0.290), ("gear_kcvt4", 20, 0.310),
    ("per_token_q2", 64, 0.217), ("kivi2", 64, 0.217),
    ("gear_l_kivi2", 64, 0.236), ("gear_kivi2", 64, 0.276),
]

N, D, HEADS, DH = 1156, 4096, 32, 128  # GSM8k: 900 prefill + 256 generated


def run():
    worst = 0.0
    for name, nb, paper in TABLE9:
        pol = dataclasses.replace(named_policy(name), buffer_size=nb)
        ours = metrics.kv_size_fraction(pol, N, D, num_heads=HEADS, head_dim=DH)
        gap = abs(ours - paper)
        worst = max(worst, gap)
        emit(f"table9_kvsize/{name}", 0.0,
             f"ours={ours:.3f} paper={paper:.3f} gap={gap:.3f}")
    emit("table9_kvsize/max_gap", 0.0, f"{worst:.3f}")

    # Fig 6 breakdown for the two recommended configs
    for name, nb in (("gear_kcvt4", 20), ("gear_kivi2", 64)):
        pol = dataclasses.replace(named_policy(name), buffer_size=nb)
        bd = metrics.kv_size_breakdown(pol, N, D, HEADS, DH)
        tot = bd.total
        emit(f"fig6_breakdown/{name}", 0.0,
             f"quant={bd.quant_bytes/tot:.2f} stats={bd.stat_bytes/tot:.2f} "
             f"buffer={bd.buffer_bytes/tot:.2f} lowrank={bd.lowrank_bytes/tot:.2f} "
             f"sparse={bd.sparse_bytes/tot:.2f}")
    # serving-engine (chunked) accounting for comparison
    for name in ("gear_kcvt4", "gear_kivi2"):
        pol = named_policy(name)
        ours = metrics.kv_size_fraction(pol, N, D, HEADS, DH, per_chunk_lowrank=True)
        emit(f"kvsize_chunked_engine/{name}", 0.0, f"fraction={ours:.3f}")
    return worst


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the emitted rows to this JSON file")
    args = ap.parse_args()
    run()
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
