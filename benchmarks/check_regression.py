"""CI bench regression gate: diff bench-out/*.json against baseline.json.

Benches emit rows whose ``value`` field is a machine-readable metric
(:func:`benchmarks.common.emit`).  This script compares every metric named
in the committed baseline against the freshly-measured rows and fails on:

* ``*tok_per_s*``  — throughput more than ``--tol`` (default 15%) BELOW the
  baseline (timing metrics; slack absorbs runner jitter, a real fused-path
  or scheduler regression is far larger);
* ``*_over_*``     — relative ratios (e.g. fused-vs-XLA attend), same
  ``--tol`` floor; both sides are measured in the same run, so these are
  machine-independent and catch a path regression even when absolute tok/s
  baselines were recorded on different hardware;
* ``*nbytes*``     — ANY growth (byte accounting is deterministic: cache
  growth means the compressed layout regressed, so zero tolerance);
* ``*peak_bytes*`` — growth beyond ``--mem-tol`` (default 5%): these come
  from XLA's compiled memory analysis (bench_prefill's streaming-vs-
  monolithic peak), which is deterministic per jax version but may shift a
  few percent across compiler releases — a real peak-memory regression
  (e.g. the streaming pipeline re-materializing FP16 history) is far
  larger;
* ``*hit_rate*`` / ``*toks_saved*`` — ANY drop (the canned shared-prefix
  workload of bench_prefix is deterministic: fewer trie hits means the
  prefix cache stopped matching or admission broke, so zero tolerance);
* ``*ok_rate*`` — ANY drop (bench_throughput ``--chaos``: the fault-FREE
  path with the resilience layer armed must keep every request ``OK`` —
  a drop means retries/valve/quarantine fired on healthy traffic);
* ``*overhead_frac*`` — growth ABOVE the committed ceiling (bench_throughput
  ``--obs`` / bench_prefix ``--obs``: fractional tok/s lost to telemetry;
  the baseline is a ceiling, not a floor — lower is better, and exceeding
  it means the observability layer started costing real throughput);
* ``*concurrent_over*`` — bench_paged's fixed-byte packing ratio: pure page
  arithmetic from the engine's own byte accounting, so ANY drop fails, plus
  an absolute >= 3x floor (the paged layout's headline capacity claim);
* metrics missing from the bench output (a silently-dropped bench row must
  fail loudly, not skip the gate).

Refresh the baseline after an intentional change with::

    python -m benchmarks.bench_throughput --smoke --json bench-out/throughput.json
    python -m benchmarks.check_regression bench-out --write-baseline --derate 0.6

``--derate`` scales the recorded *absolute* tok/s floors (ratios and byte
counts stay exact) so a baseline measured on a fast dev machine does not
false-fail on slower CI runners.  The committed baseline keeps the absolute
floors aggressively derated (~0.4) as a catastrophic-collapse backstop; the
``*_over_*`` ratio rows are the sensitive, machine-independent guard, and
the smoke-bench CI job installs the ``jax04`` pin so runs compare like with
like.

Exit status: 0 clean, 1 on any regression (CI fails the step).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
CONCURRENCY_FLOOR = 3.0      # bench_paged: min concurrent-contexts ratio


def load_rows(bench_dir: str) -> dict[str, float]:
    """name -> value for every row (in any bench-out JSON) carrying a value."""
    rows: dict[str, float] = {}
    paths = sorted(glob.glob(os.path.join(bench_dir, "*.json")))
    if not paths:
        sys.exit(f"check_regression: no *.json under {bench_dir!r}")
    for path in paths:
        with open(path) as f:
            for row in json.load(f):
                if row.get("value") is not None:
                    rows[row["name"]] = float(row["value"])
    return rows


def governed(name: str) -> bool:
    return ("tok_per_s" in name or "nbytes" in name or "peak_bytes" in name
            or "_over_" in name or "hit_rate" in name or "toks_saved" in name
            or "ok_rate" in name or "overhead_frac" in name)


def check(baseline: dict[str, float], rows: dict[str, float],
          tol: float, mem_tol: float = 0.05) -> list[str]:
    failures = []
    for name, ref in sorted(baseline.items()):
        new = rows.get(name)
        if new is None:
            failures.append(f"{name}: missing from bench output (baseline {ref:g})")
        elif "nbytes" in name and new > ref:
            failures.append(f"{name}: {new:g} bytes > baseline {ref:g} (any growth fails)")
        elif "overhead_frac" in name:
            # telemetry cost ceiling: the committed value is the MAXIMUM
            # tolerable fraction of tok/s lost with observability enabled
            if new > ref + 1e-9:
                failures.append(
                    f"{name}: {new:g} > ceiling {ref:g} (telemetry overhead "
                    "budget exceeded)")
            else:
                print(f"ok   {name}: {new:g} (ceiling {ref:g})")
        elif (("hit_rate" in name or "toks_saved" in name
               or "ok_rate" in name) and new < ref - 1e-9):
            failures.append(
                f"{name}: {new:g} < baseline {ref:g} (deterministic canned "
                "workload: any drop fails)")
        elif "peak_bytes" in name:
            if new > ref * (1.0 + mem_tol):
                failures.append(
                    f"{name}: {new:g} bytes > {ref * (1.0 + mem_tol):g} "
                    f"(baseline {ref:g} + {mem_tol:.0%} compiler headroom)")
            else:
                print(f"ok   {name}: {new:g} (baseline {ref:g})")
        elif "concurrent_over" in name:
            # bench_paged's packing ratio is pure byte math (page counts from
            # the engine's own accounting) — deterministic, so any drop fails,
            # and the paper-level claim keeps an absolute >= 3x floor
            if new < CONCURRENCY_FLOOR - 1e-9:
                failures.append(
                    f"{name}: {new:g}x below the {CONCURRENCY_FLOOR:g}x "
                    "concurrency floor (paged packing broke)")
            elif new < ref - 1e-9:
                failures.append(
                    f"{name}: {new:g} < baseline {ref:g} (deterministic page "
                    "math: any drop fails)")
            else:
                print(f"ok   {name}: {new:g} (baseline {ref:g})")
        elif "nbytes" not in name and new < ref * (1.0 - tol):
            failures.append(
                f"{name}: {new:g} < {ref * (1.0 - tol):g} "
                f"(baseline {ref:g} - {tol:.0%} tolerance)")
        else:
            print(f"ok   {name}: {new:g} (baseline {ref:g})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench_dir", help="directory of bench *.json row dumps")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tol", type=float, default=0.15,
                    help="allowed fractional tok_per_s drop (default 0.15)")
    ap.add_argument("--mem-tol", type=float, default=0.05,
                    help="allowed fractional *peak_bytes* growth (default 0.05)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the governed metrics of this run as the new baseline")
    ap.add_argument("--derate", type=float, default=1.0,
                    help="scale recorded absolute tok_per_s floors at "
                         "--write-baseline time (cross-machine headroom)")
    args = ap.parse_args(argv)

    rows = load_rows(args.bench_dir)
    if args.write_baseline:
        # derate only ABSOLUTE throughput floors; *_over_* ratio rows are
        # measured within one run and must stay exact even when their name
        # contains tok_per_s (e.g. prefill_tok_per_s/streaming_over_monolithic)
        base = {n: v * (args.derate if "tok_per_s" in n and "_over_" not in n
                        else 1.0)
                for n, v in sorted(rows.items()) if governed(n)}
        if not base:
            sys.exit("check_regression: no governed (*tok_per_s* / *nbytes* / "
                     "*peak_bytes* / *_over_*) rows to baseline")
        with open(args.baseline, "w") as f:
            json.dump(base, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(base)} baseline metrics to {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(baseline, rows, args.tol, args.mem_tol)
    for msg in failures:
        print(f"FAIL {msg}", file=sys.stderr)
    if failures:
        print(f"check_regression: {len(failures)} regression(s) vs {args.baseline}",
              file=sys.stderr)
        return 1
    print(f"check_regression: {len(baseline)} metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
