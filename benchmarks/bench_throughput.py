"""Paper Fig 3a (time breakdown) + Fig 3c (throughput).

Wall-clock GPU throughput is not reproducible on CPU, so this bench reports
BOTH:
  (1) the roofline-model predicted decode throughput — decode on a V100 is
      HBM-bandwidth-bound, so tokens/s ≈ batch / ((weights + batch·KV)/BW);
      GEAR's gain comes from the larger feasible batch at equal memory —
      exactly the mechanism behind the paper's 2.1×–5.07×;
  (2) measured CPU-relative step times for the compression components
      (Fig 3a): quantization / low-rank / sparse vs model forward.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, kv_like, timeit
from benchmarks.bench_memory import kv_bytes_per_seq, max_batch, N_IN, N_GEN, GB
from repro.configs import get_config, smoke_config
from repro.core import gear, lowrank, outlier, quant
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig

V100_BW = 900e9  # bytes/s


def predicted_throughput(policy, cfg, batch):
    weights = cfg.param_count() * 1.0          # 8-bit
    step_bytes = weights + batch * kv_bytes_per_seq(policy, cfg, N_IN + N_GEN)
    return batch / (step_bytes / V100_BW)


def fig3c(cfg):
    pol2 = named_policy("gear_kivi2")
    out = {}
    for name, pol in (("fp16", FP16), ("gear2", pol2)):
        b = max_batch(pol, cfg)
        tps = predicted_throughput(pol, cfg, b)
        out[name] = (b, tps)
        emit(f"fig3c_throughput/{name}", 0.0,
             f"max_batch={b} predicted_tok_per_s={tps:.0f}")
    ratio = out["gear2"][1] / out["fp16"][1]
    emit("fig3c_throughput/ratio", 0.0, f"{ratio:.2f}x paper=2.1-5.07x")
    return ratio


def fig3a_breakdown(key):
    """Component timings of one compression event (CPU-relative)."""
    x = kv_like(key, (1, 8, 64, 128))
    pol = named_policy("gear_kivi2")
    scheme, group = pol.scheme_for("k")
    t_quant = timeit(lambda: quant.dequantize(quant.quantize(x, 2, scheme, group)))
    t_low = timeit(lambda: lowrank.power_iteration(x, 4, 4))
    t_sparse = timeit(lambda: outlier.filter_outliers(x, 0.02, "token"))
    # model forward step for scale (small model decode)
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    eng = Engine(m, params, EngineConfig(
        batch=1, capacity=96, policy=dataclasses.replace(pol, buffer_size=16, group=16)))
    batch = {"tokens": jnp.zeros((1, 24), jnp.int32)}
    _, caches = eng.prefill(batch)
    tok = {"tokens": jnp.zeros((1, 1), jnp.int32)}
    t_fwd = timeit(lambda: eng.decode(tok, eng.init_caches(), 24))
    total = t_quant + t_low + t_sparse + t_fwd
    for name, t in (("quant", t_quant), ("lowrank", t_low), ("sparse", t_sparse),
                    ("forward_other", t_fwd)):
        emit(f"fig3a_breakdown/{name}", t, f"{100*t/total:.1f}%")
    return {"quant": t_quant, "lowrank": t_low, "sparse": t_sparse, "fwd": t_fwd}


def cpu_relative_decode(key):
    """Measured CPU decode step: fp16 vs GEAR caches (relative only)."""
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 24), 0, cfg.vocab_size)}
    times = {}
    for name, pol in (("fp16", FP16),
                      ("gear4", dataclasses.replace(named_policy("gear_kcvt4"),
                                                    buffer_size=16))):
        eng = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=pol))
        _, caches = eng.prefill(batch)
        tok = {"tokens": jnp.zeros((2, 1), jnp.int32)}
        eng.decode(tok, caches, 24)  # compile
        _, caches = eng.prefill(batch)
        times[name] = timeit(lambda c=caches: eng._decode(eng.params, tok, c, 24),
                             iters=1, warmup=0)
        emit(f"cpu_decode_us/{name}", times[name], "CPU-relative only")
    return times


def run(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = get_config("llama2-7b")
    ratio = fig3c(cfg)
    assert 1.5 < ratio < 8.0, ratio
    fig3a_breakdown(key)
    cpu_relative_decode(key)
    return ratio


if __name__ == "__main__":
    run()
