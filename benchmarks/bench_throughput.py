"""Paper Fig 3a (time breakdown) + Fig 3c (throughput) + scheduler modes.

Wall-clock GPU throughput is not reproducible on CPU, so this bench reports
BOTH:
  (1) the roofline-model predicted decode throughput — decode on a V100 is
      HBM-bandwidth-bound, so tokens/s ≈ batch / ((weights + batch·KV)/BW);
      GEAR's gain comes from the larger feasible batch at equal memory —
      exactly the mechanism behind the paper's 2.1×–5.07×;
  (2) measured CPU-relative step times for the compression components
      (Fig 3a): quantization / low-rank / sparse vs model forward;
  (3) wave vs slot-level continuous batching on a mixed-length workload —
      relative tokens/s of the two scheduler modes (CPU-relative but the
      ratio is scheduling-structural: waves decode every slot to the wave's
      max budget, continuous splices the next request the moment a slot
      frees).  ``--smoke --json`` runs (3) + (4) for the CI artifact;
  (4) fused vs XLA decode-attend on the same mixed-budget continuous
      workload (``--fused`` runs only this) — the ragged fused ``gear_attend``
      path against the portable jnp ``cache.attend`` path.  On CPU the fused
      path runs the jnp oracle, so the number is layout-relative only; on
      TPU it is the Pallas kernel and the gap is the paper's fused-dequant
      decode win.

Rows that the CI regression gate (benchmarks/check_regression.py) diffs
against benchmarks/baseline.json carry a machine-readable ``value``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, kv_like, timeit
from benchmarks.bench_memory import kv_bytes_per_seq, max_batch, N_IN, N_GEN, GB
from repro.configs import get_config, smoke_config
from repro.core import gear, lowrank, outlier, quant
from repro.core.policy import FP16, named_policy
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig

V100_BW = 900e9  # bytes/s


def predicted_throughput(policy, cfg, batch):
    weights = cfg.param_count() * 1.0          # 8-bit
    step_bytes = weights + batch * kv_bytes_per_seq(policy, cfg, N_IN + N_GEN)
    return batch / (step_bytes / V100_BW)


def fig3c(cfg):
    pol2 = named_policy("gear_kivi2")
    out = {}
    for name, pol in (("fp16", FP16), ("gear2", pol2)):
        b = max_batch(pol, cfg)
        tps = predicted_throughput(pol, cfg, b)
        out[name] = (b, tps)
        emit(f"fig3c_throughput/{name}", 0.0,
             f"max_batch={b} predicted_tok_per_s={tps:.0f}")
    ratio = out["gear2"][1] / out["fp16"][1]
    emit("fig3c_throughput/ratio", 0.0, f"{ratio:.2f}x paper=2.1-5.07x")
    return ratio


def fig3a_breakdown(key):
    """Component timings of one compression event (CPU-relative)."""
    x = kv_like(key, (1, 8, 64, 128))
    pol = named_policy("gear_kivi2")
    scheme, group = pol.scheme_for("k")
    t_quant = timeit(lambda: quant.dequantize(quant.quantize(x, 2, scheme, group)))
    t_low = timeit(lambda: lowrank.power_iteration(x, 4, 4))
    t_sparse = timeit(lambda: outlier.filter_outliers(x, 0.02, "token"))
    # model forward step for scale (small model decode)
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    eng = Engine(m, params, EngineConfig(
        batch=1, capacity=96, policy=dataclasses.replace(pol, buffer_size=16, group=16)))
    batch = {"tokens": jnp.zeros((1, 24), jnp.int32)}
    _, caches = eng.prefill(batch)
    tok = {"tokens": jnp.zeros((1, 1), jnp.int32)}
    t_fwd = timeit(lambda: eng.decode(tok, eng.init_caches(), 24))
    total = t_quant + t_low + t_sparse + t_fwd
    for name, t in (("quant", t_quant), ("lowrank", t_low), ("sparse", t_sparse),
                    ("forward_other", t_fwd)):
        emit(f"fig3a_breakdown/{name}", t, f"{100*t/total:.1f}%")
    return {"quant": t_quant, "lowrank": t_low, "sparse": t_sparse, "fwd": t_fwd}


def cpu_relative_decode(key):
    """Measured CPU decode step: fp16 vs GEAR caches (relative only)."""
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 24), 0, cfg.vocab_size)}
    times = {}
    for name, pol in (("fp16", FP16),
                      ("gear4", dataclasses.replace(named_policy("gear_kcvt4"),
                                                    buffer_size=16))):
        eng = Engine(m, params, EngineConfig(batch=2, capacity=96, policy=pol))
        _, caches = eng.prefill(batch)
        tok = {"tokens": jnp.zeros((2, 1), jnp.int32)}
        eng.decode(tok, caches, 24)  # compile
        _, caches = eng.prefill(batch)
        times[name] = timeit(lambda c=caches: eng._decode(eng.params, tok, c, 24),
                             iters=1, warmup=0)
        emit(f"cpu_decode_us/{name}", times[name], "CPU-relative only")
    return times


def _mixed_requests(n_reqs: int, max_prompt: int, vocab: int, seed: int = 0):
    """Mixed-length synthetic workload: raw prompt lengths 4..max_prompt
    (no scheduler padding), budgets cycling 8..64."""
    from repro.serving.scheduler import Request
    rng = np.random.RandomState(seed)
    budgets = [8, 16, 32, 64]
    return [Request(rid=i,
                    tokens=rng.randint(1, vocab, size=rng.randint(4, max_prompt + 1)),
                    max_new_tokens=budgets[i % len(budgets)])
            for i in range(n_reqs)]


def wave_vs_continuous(key, n_reqs: int = 12, batch: int = 4):
    """Tokens/s of wave vs slot-level continuous batching (same workload)."""
    from repro.serving.scheduler import Scheduler
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    pol = dataclasses.replace(named_policy("gear_kcvt4"),
                              buffer_size=16, rank=2, rank_decode=2)
    max_prompt = 16
    eng = Engine(m, params, EngineConfig(batch=batch, capacity=96, policy=pol,
                                         eos_id=-1))

    def drive(mode: str) -> float:
        sched = Scheduler(eng)
        for r in _mixed_requests(n_reqs, max_prompt, cfg.vocab_size):
            sched.submit(r)
        t0 = time.time()
        results = getattr(sched, mode)()
        wall = time.time() - t0
        return sum(len(r.tokens) for r in results) / wall

    out = {}
    for mode, tag in (("run", "wave"), ("run_continuous", "continuous")):
        # warmup drives the IDENTICAL workload (same seed): prompts are
        # raw-length now, so every distinct prompt length is its own jit
        # prefill program and all of them must compile before timing
        drive(mode)
        out[tag] = drive(mode)
        emit(f"throughput_sched/{tag}", 0.0, f"tok_per_s={out[tag]:.1f}",
             value=out[tag])
    ratio = out["continuous"] / out["wave"]
    emit("throughput_sched/continuous_over_wave", 0.0,
         f"{ratio:.2f}x (mixed budgets 8-64, batch={batch}, n={n_reqs})",
         value=ratio)
    nbytes = Engine.cache_nbytes(eng.init_caches())
    emit("cache_nbytes/bench_engine_gear", 0.0,
         f"{nbytes} bytes (batch={batch}, cap={eng._cap()})", value=nbytes)
    assert ratio >= 1.0, f"continuous batching slower than waves: {ratio:.2f}x"
    return ratio


def fused_vs_xla(key, n_reqs: int = 8, batch: int = 4):
    """Continuous-mode decode throughput: fused gear_attend vs jnp attend.

    Identical mixed-budget workload and scheduler either way; only the
    decode-attend path differs (``EngineConfig.fused``).  The ragged per-slot
    masking inside the kernel is what lets the continuous batches take the
    fused path at all — before it they silently fell back to XLA attend.
    """
    from repro.serving.scheduler import Scheduler
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    pol = dataclasses.replace(named_policy("gear_kcvt4"),
                              buffer_size=16, rank=2, rank_decode=2)
    max_prompt = 16
    out = {}
    for tag, fused in (("xla", "off"), ("fused", "auto")):
        eng = Engine(m, params, EngineConfig(batch=batch, capacity=96, policy=pol,
                                             eos_id=-1, fused=fused))

        def drive(n: int):
            sched = Scheduler(eng)
            for r in _mixed_requests(n, max_prompt, cfg.vocab_size):
                sched.submit(r)
            sched.run_continuous()
            st = sched.last_stats
            return st["tokens"] / max(st["decode_s"], 1e-9), st["attend_path"]

        drive(2 * batch)                     # compile warmup
        tok_s, path = drive(n_reqs)
        out[tag] = tok_s
        emit(f"throughput_fused/decode_tok_per_s_{tag}", 0.0,
             f"{tok_s:.1f} tok/s attend_path={path}", value=tok_s)
    ratio = out["fused"] / out["xla"]
    emit("throughput_fused/fused_over_xla", 0.0,
         f"{ratio:.2f}x (CPU oracle vs XLA attend; on TPU = Pallas kernel)",
         value=ratio)
    return ratio


def chaos_smoke(key, n_reqs: int = 10, batch: int = 4):
    """Resilience smoke (``--chaos``): the same mixed-length continuous
    workload run fault-free and under a seeded fault schedule.

    Emits:

    * ``chaos/faultfree_ok_rate`` — fraction of requests finishing ``OK``
      on the clean path with the resilience layer armed (retry policy,
      typed statuses, audits).  The regression gate's zero-drop rule pins
      it at 1.0: the resilience machinery must never reject, degrade, or
      fail a healthy request.
    * ``chaos/degraded_decode_tok_per_s`` — decode tok/s under injected
      pool exhaustion / NaN chunks / decode faults (informational:
      degradation should be a slope, not a cliff — the run must still
      terminate with every request accounted for and audits clean).
    * ``chaos/fault_terminal_rate`` — fraction of requests terminally
      REJECTED/FAILED under that schedule (informational).
    """
    from repro.serving import (FakeClock, FaultInjector, RequestStatus,
                               RetryPolicy)
    from repro.serving.scheduler import Scheduler
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    pol = dataclasses.replace(named_policy("gear_kcvt4"),
                              buffer_size=16, rank=2, rank_decode=2)
    eng = Engine(m, params, EngineConfig(batch=batch, capacity=96, policy=pol,
                                         eos_id=-1, layout="paged"))

    def drive(faults=None):
        eng.attach_faults(None)          # detach the previous run's injector
        sched = Scheduler(eng, faults=faults,
                          retry=RetryPolicy(max_attempts=3, backoff_s=0.01))
        for r in _mixed_requests(n_reqs, 16, cfg.vocab_size):
            sched.submit(r)
        results = sched.run_continuous()
        rep = sched.audit(results)       # zero leaks even under faults
        assert rep["ok"], rep["issues"]
        return results, sched.last_stats

    drive()                              # compile warmup
    clean, cstats = drive()
    ok_rate = sum(r.status is RequestStatus.OK for r in clean) / len(clean)
    emit("chaos/faultfree_ok_rate", 0.0,
         f"{len(clean)} requests, statuses={cstats['statuses']}",
         value=ok_rate)
    inj = FaultInjector(seed=0, clock=FakeClock(),
                        rates={"pool_exhausted": 0.2, "nan_chunk": 0.1,
                               "decode_error": 0.05})
    faulty, fstats = drive(inj)
    tok_s = fstats["tokens"] / max(fstats["decode_s"], 1e-9)
    fired = {k: v for k, v in inj.fired.items() if v}
    emit("chaos/degraded_decode_tok_per_s", 0.0,
         f"{tok_s:.1f} tok/s under seeded faults fired={fired}", value=tok_s)
    n_bad = sum(r.status in (RequestStatus.REJECTED, RequestStatus.FAILED)
                for r in faulty)
    emit("chaos/fault_terminal_rate", 0.0,
         f"{n_bad}/{len(faulty)} REJECTED/FAILED, "
         f"statuses={fstats['statuses']}")
    assert ok_rate == 1.0, \
        f"fault-free path failed requests: {cstats['statuses']}"
    return ok_rate


def obs_smoke(key, n_reqs: int = 8, batch: int = 4):
    """Telemetry overhead + coverage smoke (``--obs``).

    The same mixed-length continuous workload served by two engines that
    differ ONLY in ``EngineConfig.obs`` — off vs full telemetry (metrics +
    tracing + fidelity probes at ``every_n=1``).  Emits
    ``obs/overhead_frac`` = fractional decode tok/s lost with telemetry
    on (median of 3 interleaved drives, clamped at 0); the CI regression
    gate holds it at the committed ceiling.  Also asserts, in-bench, the
    ISSUE 10 acceptance bundle:

    * traces cover 100% of submitted rids, exactly one per rid, with
      statuses matching the scheduler's audit;
    * fidelity probes report per-layer error for >= 1 sampled chunk on
      every GEAR layer;
    * the Prometheus exposition and JSON snapshot both round-trip.
    """
    import json as _json

    from repro.obs import ObsConfig
    from repro.obs.registry import parse_prometheus
    from repro.serving.scheduler import Scheduler
    cfg = smoke_config("llama2-7b")
    m = build_model(cfg)
    params = m.init(key)
    pol = dataclasses.replace(named_policy("gear_kcvt4"),
                              buffer_size=16, rank=2, rank_decode=2)
    # prompts up to 2 chunks long so fidelity probes see closed chunks
    max_prompt = 32
    base = EngineConfig(batch=batch, capacity=96, policy=pol, eos_id=-1)
    eng_off = Engine(m, params, base)
    eng_on = Engine(m, params,
                    dataclasses.replace(base,
                                        obs=ObsConfig(fidelity_every_n=1)))

    def drive(eng):
        if eng.obs is not None:
            eng.obs.tracer.reset()   # one trace per rid per measured drive
        sched = Scheduler(eng)
        reqs = _mixed_requests(n_reqs, max_prompt, cfg.vocab_size)
        for r in reqs:
            sched.submit(r)
        results = sched.run_continuous()
        st = sched.last_stats
        return st["tokens"] / max(st["decode_s"], 1e-9), sched, results, reqs

    drive(eng_off)                   # compile warmup (same jit programs,
    drive(eng_on)                    # but each engine owns its own cache)
    offs, ons = [], []
    for _ in range(3):               # interleaved: drift hits both equally
        offs.append(drive(eng_off)[0])
        tok_on, sched, results, reqs = drive(eng_on)
        ons.append(tok_on)
    off_med = sorted(offs)[1]
    on_med = sorted(ons)[1]
    overhead = max(0.0, 1.0 - on_med / off_med)

    # --- acceptance: trace coverage matches the scheduler's own audit
    o = eng_on.obs
    cov = o.tracer.coverage([r.rid for r in reqs])
    assert cov["complete"], cov
    assert cov["statuses"] == {r.rid: str(r.status) for r in results}, cov
    rep = sched.audit(results)
    assert rep["ok"], rep["issues"]

    # --- acceptance: >= 1 sampled chunk with per-layer error on every
    # GEAR layer (global index r * len(pattern) + i, see FidelityProbe)
    assert o.fidelity is not None and o.fidelity.reports, \
        "no fidelity reports despite every_n=1 and multi-chunk prompts"
    pat = len(cfg.layer_pattern)
    want_layers = {r * pat + i for r in range(cfg.pattern_repeats)
                   for i in o.fidelity._gear_pos}
    layers_seen = {lr["layer"] for rp in o.fidelity.reports
                   for lr in rp["layers"]}
    assert layers_seen == want_layers, (layers_seen, want_layers)
    assert all("k_rel_err" in lr and "v_rel_err" in lr
               for rp in o.fidelity.reports for lr in rp["layers"])

    # --- acceptance: exports round-trip
    parsed = parse_prometheus(o.to_prometheus())
    subm = o.registry.get("serving_requests_submitted_total").value()
    assert parsed[("serving_requests_submitted_total", ())] == subm > 0
    snap = _json.loads(o.to_json())
    assert snap["schema"] == o.snapshot()["schema"]
    assert {mt["name"] for mt in snap["metrics"]} == set(o.registry.names())
    chrome = o.tracer.to_chrome()
    assert len(chrome["traceEvents"]) > 0

    emit("obs/decode_tok_per_s_off", 0.0, f"{off_med:.1f} tok/s telemetry off")
    emit("obs/decode_tok_per_s_on", 0.0,
         f"{on_med:.1f} tok/s metrics+traces+fidelity(every_n=1)")
    emit("obs/overhead_frac", 0.0,
         f"{overhead:.3f} fractional decode tok/s lost (median of 3, "
         f"gate <= 0.05)", value=overhead)
    assert overhead < 0.25, \
        f"telemetry overhead {overhead:.1%} is pathological"
    return overhead


def run(key=None, smoke: bool = False, fused_only: bool = False):
    key = key if key is not None else jax.random.PRNGKey(0)
    if fused_only:
        return fused_vs_xla(key)
    sched_ratio = wave_vs_continuous(key)
    fused_vs_xla(key)
    if smoke:
        return sched_ratio
    cfg = get_config("llama2-7b")
    ratio = fig3c(cfg)
    assert 1.5 < ratio < 8.0, ratio
    fig3a_breakdown(key)
    cpu_relative_decode(key)
    return ratio


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scheduler + fused-attend comparisons only")
    ap.add_argument("--fused", action="store_true",
                    help="only the fused-vs-XLA decode-attend comparison")
    ap.add_argument("--chaos", action="store_true",
                    help="resilience smoke: fault-free ok-rate + degraded "
                         "throughput under a seeded fault schedule")
    ap.add_argument("--obs", action="store_true",
                    help="telemetry smoke: decode tok/s overhead with full "
                         "observability on, plus coverage/fidelity/round-"
                         "trip acceptance asserts")
    ap.add_argument("--json", default=None,
                    help="also write the emitted rows to this JSON file")
    args = ap.parse_args()
    if args.chaos:
        chaos_smoke(jax.random.PRNGKey(0))
    elif args.obs:
        obs_smoke(jax.random.PRNGKey(0))
    else:
        run(smoke=args.smoke, fused_only=args.fused)
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
