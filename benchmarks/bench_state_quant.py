"""Beyond-paper bench: GEAR-style compression of *recurrent* state.

GEAR is inapplicable to attention-free archs (rwkv6-3b) because there is no
growing KV cache — but the recipe's decomposition transfers to the fixed
[H, Dk, Dv] wkv state when batch-serving thousands of long-lived sessions
(state memory = B·L·H·Dk·Dv·4B; rwkv6-3b at B=4096 ≈ 86 GB f32).  This bench
quantifies it: quantize the state per (head, Dk) vector + rank-r residual,
and measure both the state-size fraction and the perturbation of the next
few decoded outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import lowrank, quant
from repro.models import linear_scan


def _realistic_state(key, B=2, H=4, Dk=16, Dv=16, steps=96):
    """Run the actual recurrence on random inputs to get a realistic state."""
    r = jax.random.normal(key, (B, H, steps, Dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, steps, Dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, steps, Dv))
    lw = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                            (B, H, steps, Dk)) - 1.0)
    _, state = linear_scan.chunked_scan(r, k, v, lw, chunk=32)
    return state, (r, k, v, lw)


def run(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    state, (r, k, v, lw) = _realistic_state(key)
    B, H, Dk, Dv = state.shape

    for bits, rank in ((8, 0), (4, 0), (4, 4), (2, 4)):
        qt = quant.quantize(state, bits, "per_token")      # per (…, Dk) row
        sh = quant.dequantize(qt)
        size = bits / 32
        if rank:
            resid = state - sh
            a, b = lowrank.power_iteration(resid.reshape(B * H, Dk, Dv), rank, 4)
            sh = sh + lowrank.apply_lowrank(a, b).reshape(state.shape)
            size += 2 * rank * (Dk + Dv) / (Dk * Dv) * 0.5  # bf16 factors vs f32
        err = float(jnp.linalg.norm(state - sh) / jnp.linalg.norm(state))
        # downstream: decode 8 more tokens from exact vs compressed state
        y_exact, _ = linear_scan.chunked_scan(r[:, :, :8], k[:, :, :8], v[:, :, :8],
                                              lw[:, :, :8], chunk=8, state0=state)
        y_comp, _ = linear_scan.chunked_scan(r[:, :, :8], k[:, :, :8], v[:, :, :8],
                                             lw[:, :, :8], chunk=8, state0=sh)
        out_err = float(jnp.linalg.norm(y_exact - y_comp) / jnp.linalg.norm(y_exact))
        tag = f"{bits}bit" + (f"+r{rank}" if rank else "")
        emit(f"beyond_state_quant/{tag}", 0.0,
             f"state_frac={size:.3f} state_err={err:.4f} decode_out_err={out_err:.4f}")
    return None


if __name__ == "__main__":
    run()
