"""Streaming vs monolithic prefill: throughput + measured peak live bytes.

The paper's headline is throughput *and* peak memory (up to 2.29x smaller
peak); this bench pins the prefill half of that claim on the serving stack:

* **peak live bytes** — XLA's compiled memory analysis (temp workspace +
  outputs) of the jitted ``Model.prefill`` program for each mode.  The
  monolithic pipeline materializes every layer's full FP16 K/V (stacked
  across the layer scan) before one batched compression event; streaming
  prefill holds the compressed cache plus one ``n_b``-token chunk, so its
  peak must be far below 0.75x monolithic at 4k-token prompts.
* **prefill tok/s** — median wall time over the same 4k-token prompt with a
  paper-geometry GEAR cache (Dh=128, n_b=64, GEAR-KCVT-4bit).  Streaming
  attends the compressed history through chunk-prefix views (most of the
  causal triangle is skipped), so it must land within 10% of (CPU: typically
  above) the monolithic path.

Both gates are enforced in-bench and, via the ``value`` rows, by the CI
regression gate (benchmarks/check_regression.py): ``prefill_tok_per_s/*``
rows under the throughput rule, ``prefill_peak_bytes/*`` rows under the
any-meaningful-growth rule, and the two ``*_over_*`` ratio rows as the
machine-independent guard.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.core.policy import named_policy
from repro.models.model import build_model

# Paper-geometry KV cache (llama-class head_dim / chunk / policy) on a
# reduced residual stream so the bench runs on CPU in CI.
BENCH_CFG = ModelConfig(name="bench-prefill", family="dense", num_layers=2,
                        d_model=256, num_heads=4, num_kv_heads=2,
                        head_dim=128, d_ff=512, vocab_size=512)
PROMPT_LEN = 4096
PEAK_LIMIT = 0.75   # streaming peak must be below this fraction of monolithic
TOKS_FLOOR = 0.90   # and within 10% of monolithic tok/s (or better)


def _peak_bytes(compiled) -> int:
    """Peak live bytes of one compiled prefill: temp workspace + outputs."""
    ma = compiled.memory_analysis()
    return int(ma.temp_size_in_bytes + ma.output_size_in_bytes)


def _measure(model, params, policy, mode: str, iters: int):
    batch = {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}
    fn = jax.jit(lambda p, b: model.prefill(p, b, policy, PROMPT_LEN,
                                            prefill_mode=mode))
    compiled = fn.lower(params, batch).compile()
    peak = _peak_bytes(compiled)
    # time the AOT executable directly — on jax 0.4.x the lowered/compiled
    # program never enters the jit dispatch cache, so calling fn() here
    # would silently recompile the whole 4k-token prefill
    jax.block_until_ready(compiled(params, batch))
    ts = []
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(compiled(params, batch))
        ts.append(time.time() - t0)
    ts.sort()
    return peak, PROMPT_LEN / ts[len(ts) // 2]


def run(key=None, smoke: bool = False):
    key = key if key is not None else jax.random.PRNGKey(0)
    policy = named_policy("gear_kcvt4")
    model = build_model(BENCH_CFG)
    params = model.init(key)
    iters = 3 if smoke else 5

    out = {}
    for mode in ("monolithic", "streaming"):
        peak, tok_s = _measure(model, params, policy, mode, iters)
        out[mode] = (peak, tok_s)
        emit(f"prefill_peak_bytes/{mode}", 0.0,
             f"{peak} temp+output bytes (S={PROMPT_LEN}, gear_kcvt4)",
             value=peak)
        emit(f"prefill_tok_per_s/{mode}", 0.0, f"{tok_s:.0f} tok/s",
             value=tok_s)

    mem_ratio = out["monolithic"][0] / max(out["streaming"][0], 1)
    tok_ratio = out["streaming"][1] / out["monolithic"][1]
    emit("prefill_mem/monolithic_over_streaming", 0.0,
         f"{mem_ratio:.2f}x smaller streaming peak (gate: > {1 / PEAK_LIMIT:.2f}x)",
         value=mem_ratio)
    emit("prefill_tok_per_s/streaming_over_monolithic", 0.0,
         f"{tok_ratio:.2f}x (gate: >= {TOKS_FLOOR:.2f})", value=tok_ratio)

    assert mem_ratio > 1 / PEAK_LIMIT, (
        f"streaming prefill peak {out['streaming'][0]} not < "
        f"{PEAK_LIMIT} x monolithic {out['monolithic'][0]}")
    assert tok_ratio >= TOKS_FLOOR, (
        f"streaming prefill {tok_ratio:.2f}x of monolithic tok/s "
        f"(floor {TOKS_FLOOR})")
    return mem_ratio, tok_ratio


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing iterations (CI)")
    ap.add_argument("--json", default=None,
                    help="also write the emitted rows to this JSON file")
    args = ap.parse_args()
    run(smoke=args.smoke)
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
