"""Benchmark harness entry point — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --quick    # skip the trained-model drift bench
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    print("name,us_per_call,derived")

    from benchmarks import bench_error, bench_kvsize, bench_memory, \
        bench_prefill, bench_throughput, bench_ablation, bench_adaptive, \
        bench_state_quant
    bench_error.run()
    bench_kvsize.run()
    bench_memory.run()
    bench_prefill.run(smoke=True)
    bench_throughput.run()
    bench_ablation.run()
    bench_adaptive.run()
    bench_state_quant.run()
    if not args.quick:
        from benchmarks import bench_drift
        bench_drift.run()

    # roofline summary from dry-run artifacts, if present
    try:
        from benchmarks import roofline_report
        roofline_report.run(emit_csv=True)
    except FileNotFoundError:
        print("roofline_report,0.0,skipped (run repro.launch.dryrun first)")

    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
