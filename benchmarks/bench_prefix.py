"""Prefix-cache bench: cross-request prefill reuse on a shared-prefix
workload, at bit-exact logits parity.

The headline serving win of the radix-trie prefix cache
(``EngineConfig.prefix_cache``, repro.prefixcache): requests sharing a
chunk-aligned prompt prefix splice the prefix's compressed GEAR chunks
from the trie and run streaming prefill only on their suffix — prefill
time shrinks near-linearly with the shared fraction, and because chunk
compression is slot-invariant the warm path is **bit-identical** to a cold
prefill (asserted per request in-bench).

* **smoke** (CI): N requests sharing 80% of a 10-chunk prompt, prefix
  cache on vs off.  Gates: >= ``SPEEDUP_FLOOR``x prefill tok/s with the
  cache on, the canned workload's exact hit rate / saved-token count
  (deterministic — any drop is a trie/admission regression), and logits
  parity.  The ``value`` rows feed the CI regression gate
  (benchmarks/check_regression.py): ``prefix/prefill_tok_per_s_*`` under
  the throughput rule, ``prefix/cached_over_off`` as the
  machine-independent ratio guard, ``prefix/hit_rate`` +
  ``prefix/prefill_toks_saved`` under the exact-floor rule, and
  ``prefix/mixed_hit_rate`` + ``prefix/mixed_toks_saved`` pinning that RAW
  mixed-length prompts (unaligned suffixes, engine-side length bucketing)
  still hit the shared chunks at bit-exact warm ≡ cold logits.
* **full**: additionally sweeps the shared-prefix fraction to show the
  near-linear prefill-time reduction.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.core.policy import named_policy
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig

BENCH_CFG = ModelConfig(name="bench-prefix", family="dense", num_layers=2,
                        d_model=128, num_heads=4, num_kv_heads=2, head_dim=64,
                        d_ff=256, vocab_size=512)
POLICY = named_policy("gear_kcvt4")        # n_b = 64
N_CHUNKS = 10
PROMPT_LEN = N_CHUNKS * POLICY.buffer_size  # 640 tokens
N_REQ = 8
SHARED_CHUNKS = 8                           # 80% of the prompt
SPEEDUP_FLOOR = 1.5


def _workload(shared_chunks: int, seed: int = 0) -> list[np.ndarray]:
    """N_REQ equal-length prompts sharing their first ``shared_chunks``
    chunks (one long system prompt + per-request user suffix)."""
    nb = POLICY.buffer_size
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, BENCH_CFG.vocab_size, size=shared_chunks * nb)
    return [np.concatenate([shared, rng.randint(0, BENCH_CFG.vocab_size,
                                                size=PROMPT_LEN - shared.size)])
            for _ in range(N_REQ)]


def _workload_mixed(shared_chunks: int, seed: int = 3) -> list[np.ndarray]:
    """Mixed-length variant: the same ~80%-shared system prompt but RAW
    per-request suffix lengths in [n_b/2, n_b) — deliberately not
    chunk-aligned, so every request takes the engine's length-bucketed
    (padded-tail) prefill path while the trie still matches the shared
    chunks.  All lengths fall in ONE bucket on purpose: chunks compressed
    by different-shaped jit programs can differ in the last ulp (XLA
    codegen is per-shape), so the bitwise warm ≡ cold gate is only valid
    when the trie's seeding request and the cold reference share a bucket
    (DESIGN.md §4; cross-bucket reuse is near-lossless, not bit-exact)."""
    nb = POLICY.buffer_size
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, BENCH_CFG.vocab_size, size=shared_chunks * nb)
    return [np.concatenate([shared,
                            rng.randint(0, BENCH_CFG.vocab_size,
                                        size=rng.randint(nb // 2, nb))])
            for _ in range(N_REQ)]


def _run_workload(eng: Engine, prompts, check_against=None):
    """Prefill every prompt through ``prefill_slot``; returns (seconds,
    logits list).  ``check_against`` asserts per-request bit-parity."""
    caches = eng.init_caches()
    logits_all = []
    t0 = time.perf_counter()
    for prompt in prompts:
        logits, caches = eng.prefill_slot(
            {"tokens": jnp.asarray(prompt[None], jnp.int32)}, caches, 0)
        jax.block_until_ready(logits)
        logits_all.append(np.asarray(logits))
    dt = time.perf_counter() - t0
    if check_against is not None:
        for i, (a, b) in enumerate(zip(check_against, logits_all)):
            assert np.array_equal(a, b), f"request {i}: warm logits != cold"
    return dt, logits_all


def _measure(eng: Engine, prompts, iters: int, check_against=None):
    """Median workload seconds; each iteration starts from an empty prefix
    cache so the hit pattern is the canned one (first request cold)."""
    times = []
    logits = None
    for _ in range(iters + 1):             # +1 warmup (compiles)
        if eng.prefix_cache is not None:
            eng.prefix_cache.clear()
        dt, logits = _run_workload(eng, prompts, check_against)
        times.append(dt)
    times = sorted(times[1:])
    return times[len(times) // 2], logits


def run(smoke: bool = False):
    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(batch=1, capacity=PROMPT_LEN + POLICY.buffer_size,
                        policy=POLICY, prefill_mode="streaming")
    eng_off = Engine(model, params, base)
    eng_on = Engine(model, params,
                    dataclasses.replace(base, prefix_cache=True))
    iters = 2 if smoke else 5

    prompts = _workload(SHARED_CHUNKS)
    t_off, logits_cold = _measure(eng_off, prompts, iters)
    stats0 = eng_on.prefix_cache.stats
    t_on, _ = _measure(eng_on, prompts, iters, check_against=logits_cold)
    stats1 = eng_on.prefix_cache.stats

    total_toks = N_REQ * PROMPT_LEN
    tok_off = total_toks / t_off
    tok_on = total_toks / t_on
    speedup = tok_on / tok_off
    # per measured run: request 1 misses, requests 2..N hit the shared
    # chunks; the last eligible chunk is each request's own random suffix
    eligible = (PROMPT_LEN - 1) // POLICY.buffer_size          # 9 per request
    lookups = stats1["lookup_chunks"] - stats0["lookup_chunks"]
    hits = stats1["hit_chunks"] - stats0["hit_chunks"]
    hit_rate = hits / max(lookups, 1)
    want_rate = (N_REQ - 1) * SHARED_CHUNKS / (N_REQ * eligible)
    runs = iters + 1
    toks_saved_run = (stats1["prefill_toks_saved"]
                      - stats0["prefill_toks_saved"]) // runs

    emit("prefix/prefill_tok_per_s_off", 0.0,
         f"{tok_off:.0f} tok/s cold ({N_REQ} x {PROMPT_LEN}-token prompts, "
         f"{SHARED_CHUNKS}/{N_CHUNKS} chunks shared)", value=tok_off)
    emit("prefix/prefill_tok_per_s_cached", 0.0,
         f"{tok_on:.0f} tok/s with prefix cache", value=tok_on)
    emit("prefix/cached_over_off", 0.0,
         f"{speedup:.2f}x (gate: >= {SPEEDUP_FLOOR}x)", value=speedup)
    emit("prefix/hit_rate", 0.0,
         f"{hit_rate:.3f} of eligible prompt chunks served from the trie "
         f"(expected {want_rate:.3f})", value=hit_rate)
    emit("prefix/prefill_toks_saved", 0.0,
         f"{toks_saved_run} prefill tokens skipped per workload run",
         value=toks_saved_run)

    assert abs(hit_rate - want_rate) < 1e-9, (hit_rate, want_rate)
    assert toks_saved_run == (N_REQ - 1) * SHARED_CHUNKS * POLICY.buffer_size
    assert speedup >= SPEEDUP_FLOOR, (
        f"prefix cache speedup {speedup:.2f}x below floor {SPEEDUP_FLOOR}x")

    # ---- mixed-length workload: same shared prefix, raw unaligned suffix
    # lengths — the length-bucketed prefill path must keep warm ≡ cold
    # bit-exact AND keep hitting the shared chunks (ISSUE 8 acceptance)
    nb = POLICY.buffer_size
    mixed = _workload_mixed(SHARED_CHUNKS)
    _, mixed_cold = _measure(eng_off, mixed, 1)
    m0 = eng_on.prefix_cache.stats
    _measure(eng_on, mixed, 1, check_against=mixed_cold)
    m1 = eng_on.prefix_cache.stats

    m_lookups = m1["lookup_chunks"] - m0["lookup_chunks"]
    m_hits = m1["hit_chunks"] - m0["hit_chunks"]
    mixed_hit_rate = m_hits / max(m_lookups, 1)
    # per run: request 1 cold, requests 2..N each hit exactly the shared
    # chunks (their raw suffixes diverge); eligible chunk counts vary with
    # each prompt's raw length, so derive the expectation from the workload
    elig = [(len(p) - 1) // nb for p in mixed]
    want_mixed = (N_REQ - 1) * SHARED_CHUNKS / sum(elig)
    mixed_saved_run = (m1["prefill_toks_saved"]
                       - m0["prefill_toks_saved"]) // 2     # warmup + 1 iter

    emit("prefix/mixed_hit_rate", 0.0,
         f"{mixed_hit_rate:.3f} of eligible chunks served on RAW mixed-"
         f"length prompts ({min(map(len, mixed))}-{max(map(len, mixed))} "
         f"tokens, expected {want_mixed:.3f}); warm logits bit-equal cold",
         value=mixed_hit_rate)
    emit("prefix/mixed_toks_saved", 0.0,
         f"{mixed_saved_run} prefill tokens skipped per mixed-length run",
         value=mixed_saved_run)
    assert mixed_hit_rate > 0, "mixed-length workload never hit the trie"
    assert abs(mixed_hit_rate - want_mixed) < 1e-9, (mixed_hit_rate, want_mixed)
    assert mixed_saved_run == (N_REQ - 1) * SHARED_CHUNKS * nb

    if not smoke:
        # near-linear prefill-time reduction with shared-prefix fraction
        for shared in (0, 2, 4, 6, 9):
            sweep = _workload(shared, seed=shared + 1)
            t_sw, _ = _measure(eng_on, sweep, iters)
            emit(f"prefix/sweep_tok_per_s/shared_{shared}0pct", 0.0,
                 f"{total_toks / t_sw:.0f} tok/s at {shared}/{N_CHUNKS} "
                 "chunks shared", value=total_toks / t_sw)
    return speedup, hit_rate


def obs_overhead(iters: int = 2):
    """Telemetry cost on the prefill-bound warm path (``--obs``).

    The smoke workload re-run on a prefix-cached engine with metrics +
    tracing enabled but fidelity probes OFF (``fidelity_every_n=0``) — a
    probe's fp16 shadow prefill would swamp a prefill-only timing, and its
    cost is governed by its own budget throttle, not this gate.  Emits
    ``obs/prefix_overhead_frac`` = fractional warm prefill tok/s lost,
    gated by the CI ceiling; bit-parity vs the cold run is asserted so
    telemetry provably never touches the numerics.
    """
    from repro.obs import ObsConfig
    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(batch=1, capacity=PROMPT_LEN + POLICY.buffer_size,
                        policy=POLICY, prefill_mode="streaming",
                        prefix_cache=True)
    eng_plain = Engine(model, params, base)
    eng_obs = Engine(model, params,
                     dataclasses.replace(base,
                                         obs=ObsConfig(fidelity_every_n=0)))
    prompts = _workload(SHARED_CHUNKS)
    _, logits_cold = _measure(Engine(model, params, dataclasses.replace(
        base, prefix_cache=False)), prompts, 1)

    t_plain, _ = _measure(eng_plain, prompts, iters,
                          check_against=logits_cold)
    t_obs, _ = _measure(eng_obs, prompts, iters, check_against=logits_cold)
    overhead = max(0.0, 1.0 - t_plain / t_obs)
    assert eng_obs.obs.registry.get(
        "serving_prefill_bucket_tokens").series(), \
        "telemetry engine emitted no prefill metrics"
    emit("obs/prefix_overhead_frac", 0.0,
         f"{overhead:.3f} fractional warm prefill tok/s lost to metrics+"
         f"tracing (fidelity off; warm logits still bit-equal cold)",
         value=overhead)
    assert overhead < 0.25, \
        f"prefill telemetry overhead {overhead:.1%} is pathological"
    return overhead


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing iterations (CI)")
    ap.add_argument("--obs", action="store_true",
                    help="also measure telemetry overhead on the warm "
                         "prefill path (metrics+tracing, fidelity off)")
    ap.add_argument("--json", default=None,
                    help="also write the emitted rows to this JSON file")
    args = ap.parse_args()
    run(smoke=args.smoke)
    if args.obs:
        obs_overhead()
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
