"""Paged-pool bench: serving capacity at fixed cache bytes, paged vs dense.

The headline win of the paged compressed KV layout (``EngineConfig.layout=
'paged'``, repro.serving.pagedpool): a dense engine reserves FULL-capacity
compressed history per slot, so a short request costs the same cache bytes
as the longest one the engine can serve; the paged engine reserves
page-granular history (one page = one ``n_b``-token GEAR chunk across all
layers), so concurrency is pool-bytes-limited and short requests pack.

* **smoke** (CI): byte-exact packing math from the engine's own accounting
  (``Engine.cache_nbytes`` / ``PagePool.page_bytes`` — no timing involved),
  verified against real ``PagePool`` admissions: how many ``REQ_TOKENS``-
  token contexts fit in the bytes a ``B0``-slot dense engine reserves.
  Gate: >= ``CONCURRENCY_FLOOR``x (matches
  benchmarks/check_regression.py's ``concurrent_over`` rule).  Plus an
  end-to-end decode-throughput comparison at equal batch through
  ``Scheduler.run_continuous`` — the indirection of gathering pages by
  block table must not cost decode speed (``*_over_*`` ratio row, 15%
  tolerance).
* **full**: additionally sweeps the request length to show packing ratio
  vs how much of the dense capacity a request actually uses.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.core.policy import named_policy
from repro.models.model import build_model
from repro.serving import (Engine, EngineConfig, PagePool, Request, Scheduler,
                           pages_needed)

BENCH_CFG = ModelConfig(name="bench-paged", family="dense", num_layers=2,
                        d_model=128, num_heads=4, num_kv_heads=2, head_dim=64,
                        d_ff=256, vocab_size=512)
POLICY = named_policy("gear_kcvt4")        # 4-bit GEAR, n_b = 64
B0 = 4                                     # dense engine slots
CAPACITY = 2048                            # worst-case context the engine serves
REQ_TOKENS = 256                           # what a typical request actually uses
CONCURRENCY_FLOOR = 3.0                    # must match check_regression.py

# decode-throughput section (small geometry: equal batch, equal requests)
TP_CAPACITY = 512
TP_PROMPT = 64
TP_GEN = 32
TP_REQ = 8


def _packing(model, params):
    """Max concurrent REQ_TOKENS-token contexts inside the bytes a B0-slot
    dense engine reserves — pure byte math from engine accounting, then
    re-verified by driving the real allocator to exhaustion."""
    nb = POLICY.buffer_size
    n_chunks = CAPACITY // nb
    ecfg = EngineConfig(batch=B0, capacity=CAPACITY, policy=POLICY)
    eng_d = Engine(model, params, ecfg)
    dense_per_ctx = Engine.cache_nbytes(eng_d.init_caches()) // B0
    eng_p = Engine(model, params, dataclasses.replace(ecfg, layout="paged"))
    page_bytes = eng_p.pool.page_bytes
    # a dense slot's closed-chunk arrays hold exactly n_chunks pages' worth
    # of the pooled fields; the remainder is the per-slot FP16 streaming
    # buffer (+ scalars), which the paged layout keeps per slot too
    buf_per_slot = dense_per_ctx - n_chunks * page_bytes
    assert buf_per_slot > 0, (dense_per_ctx, n_chunks, page_bytes)

    pages_per_req = pages_needed(REQ_TOKENS, nb)
    paged_per_ctx = pages_per_req * page_bytes + buf_per_slot
    budget = B0 * dense_per_ctx
    n_paged = budget // paged_per_ctx

    # verify with the real allocator: n_paged reservations fit, no more
    pool = PagePool(n_pages=n_paged * pages_per_req + 1, batch=n_paged,
                    n_chunks=pages_per_req, page_bytes=page_bytes)
    for slot in range(n_paged):
        pool.admit(slot, pages_per_req)
    pool.check()
    assert pool.free_pages == 0 and not pool.can_admit(pages_per_req)
    total_paged = (pool.total_bytes + page_bytes            # + zero page
                   + n_paged * buf_per_slot)
    assert total_paged <= budget + page_bytes, (total_paged, budget)

    return n_paged, dense_per_ctx, paged_per_ctx, page_bytes


def _decode_tok_per_s(eng, iters: int, seed: int = 7) -> float:
    """Median decode tok/s over ``iters`` runs of the canned request queue
    through continuous batching (first run extra: compiles)."""
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, BENCH_CFG.vocab_size, size=TP_PROMPT)
               for _ in range(TP_REQ)]
    rates = []
    for it in range(iters + 1):
        sched = Scheduler(eng)
        for rid, toks in enumerate(prompts):
            sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=TP_GEN))
        results = sched.run_continuous()
        st = sched.last_stats
        assert len(results) == TP_REQ
        rates.append(st["tokens"] / st["decode_s"])
    rates = sorted(rates[1:])
    return rates[len(rates) // 2]


def run(smoke: bool = False):
    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(0))

    n_paged, dense_ctx, paged_ctx, page_bytes = _packing(model, params)
    ratio = n_paged / B0
    emit("paged/max_contexts_dense", 0.0,
         f"{B0} contexts (slot = {dense_ctx/1e3:.0f} KB at capacity "
         f"{CAPACITY})", value=B0)
    emit("paged/max_contexts_paged", 0.0,
         f"{n_paged} x {REQ_TOKENS}-token contexts in the same bytes "
         f"({paged_ctx/1e3:.0f} KB each: {pages_needed(REQ_TOKENS, POLICY.buffer_size)} "
         f"pages x {page_bytes/1e3:.1f} KB + streaming buffer)", value=n_paged)
    emit("paged/concurrent_over_dense", 0.0,
         f"{ratio:.2f}x concurrent contexts at fixed cache bytes "
         f"(gate: >= {CONCURRENCY_FLOOR}x)", value=ratio)
    assert ratio >= CONCURRENCY_FLOOR, (
        f"paged packing {ratio:.2f}x below floor {CONCURRENCY_FLOOR}x")

    iters = 2 if smoke else 5
    tcfg = EngineConfig(batch=B0, capacity=TP_CAPACITY, policy=POLICY)
    tok_dense = _decode_tok_per_s(Engine(model, params, tcfg), iters)
    eng_p = Engine(model, params, dataclasses.replace(tcfg, layout="paged"))
    tok_paged = _decode_tok_per_s(eng_p, iters)
    eng_p.pool.check()
    speed = tok_paged / tok_dense
    emit("paged/decode_tok_per_s_dense", 0.0,
         f"{tok_dense:.0f} tok/s dense ({TP_REQ} reqs, batch {B0}, "
         f"{TP_PROMPT}+{TP_GEN} tokens)", value=tok_dense)
    emit("paged/decode_tok_per_s_paged", 0.0,
         f"{tok_paged:.0f} tok/s paged (same workload)", value=tok_paged)
    emit("paged/decode_paged_over_dense", 0.0,
         f"{speed:.2f}x decode throughput, paged over dense", value=speed)

    if not smoke:
        nb = POLICY.buffer_size
        for t in (64, 256, 512, 1024, 2048):
            per = pages_needed(t, nb) * page_bytes + (dense_ctx
                                                      - (CAPACITY // nb) * page_bytes)
            emit(f"paged/sweep_concurrent/req_{t}tok", 0.0,
                 f"{(B0 * dense_ctx // per) / B0:.2f}x at {t}-token requests",
                 value=(B0 * dense_ctx // per) / B0)
    return ratio, speed


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing iterations (CI)")
    ap.add_argument("--json", default=None,
                    help="also write the emitted rows to this JSON file")
    args = ap.parse_args()
    t0 = time.time()
    run(smoke=args.smoke)
    print(f"bench_paged done in {time.time() - t0:.1f}s")
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
