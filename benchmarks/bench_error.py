"""Paper Fig 1a / 2a / 2b + Table 8: approximation error by method.

Reproduces the error *ordering* that drives the paper's accuracy results:
per-token quant > KIVI > outlier-aware > GEAR-L > GEAR at 2-bit, and the
fast-decaying residual spectrum that justifies the low-rank component.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, kv_like, timeit
from repro.core import gear, lowrank, quant
from repro.core.policy import named_policy

METHODS_2BIT = ["per_token_q2", "kivi2", "outlier_kivi2", "gear_l_kivi2", "gear_kivi2"]
METHODS_4BIT = ["per_token_q4", "kcvt4", "kivi4", "gear_l_kcvt4", "gear_kcvt4"]


def approx_error_table(key) -> dict:
    x = kv_like(key, (1, 4, 1024, 128))
    out = {}
    for name in METHODS_2BIT + METHODS_4BIT:
        err = float(gear.approx_error(x, named_policy(name), "k"))
        out[name] = err
    return out


def residual_spectrum(key, topn: int = 32) -> jnp.ndarray:
    """Fig 2b: singular-value spectrum of the quantization residual."""
    x = kv_like(key, (1, 1, 1024, 128))[0, 0]
    pol = named_policy("kivi2")
    qt = quant.quantize(x, pol.bits, *pol.scheme_for("k"))
    resid = x - quant.dequantize(qt)
    s = jnp.linalg.svd(resid, compute_uv=False)
    return s[:topn] / s[0]


def table10_h2o(key, keep_frac: float = 0.5):
    """Table 10 analogue: H2O token dropping vs GEAR on attention output.

    H2O evicts the 50 % of tokens with lowest accumulated attention weight;
    GEAR keeps every token at ~4-bit.  We measure the attention-output
    perturbation both cause — the mechanism behind H2O's accuracy collapse
    on reasoning tasks (information made invisible) vs GEAR's near-lossless
    behaviour (information kept, slightly noisy).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    H, n, dh = 4, 512, 64
    kk = kv_like(k1, (1, H, n, dh))[0]
    vv = kv_like(k2, (1, H, n, dh))[0]
    q_past = jax.random.normal(k3, (H, 16, dh))      # queries H2O has seen
    q = jax.random.normal(k4, (H, 16, dh))           # future (CoT) queries
    scale = dh ** -0.5

    def attn_out(khat, vhat, extra_mask=None):
        s_ = jnp.einsum("hqd,hnd->hqn", q, khat) * scale
        if extra_mask is not None:
            s_ = jnp.where(extra_mask[:, None, :], s_, -1e30)
        w = jax.nn.softmax(s_, axis=-1)
        return jnp.einsum("hqn,hnd->hqd", w, vhat)

    out_full = attn_out(kk, vv)
    # H2O: accumulated attention mass per token over PAST queries — future
    # (reasoning) queries attend to different tokens, which is exactly why
    # the paper finds token dropping collapses on CoT tasks.
    acc = jax.nn.softmax(jnp.einsum("hqd,hnd->hqn", q_past, kk) * scale, -1).sum(1)
    kth = jnp.sort(acc, axis=-1)[:, int(n * (1 - keep_frac))][:, None]
    keep = acc >= kth
    out_h2o = attn_out(kk, vv, extra_mask=keep)

    from repro.core.gear import compress_matrix, decompress_matrix
    pol = named_policy("gear_kcvt4")
    k_hat = decompress_matrix(compress_matrix(kk, pol, "k"))
    v_hat = decompress_matrix(compress_matrix(vv, pol, "v"))
    out_gear = attn_out(k_hat, v_hat)

    base = jnp.linalg.norm(out_full)
    e_h2o = float(jnp.linalg.norm(out_full - out_h2o) / base)
    e_gear = float(jnp.linalg.norm(out_full - out_gear) / base)
    emit("table10_h2o/h2o_drop50", 0.0, f"attn_out_rel_err={e_h2o:.4f} kv_size=50%")
    emit("table10_h2o/gear_kcvt4", 0.0, f"attn_out_rel_err={e_gear:.4f} kv_size~32%")
    assert e_gear < e_h2o, (e_gear, e_h2o)
    return e_h2o, e_gear


def run(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    errs = approx_error_table(key)
    for name, err in errs.items():
        us = timeit(lambda n=name: gear.approx_error(
            kv_like(key, (1, 2, 256, 128)), named_policy(n), "k"))
        emit(f"fig1a_error/{name}", us, f"rel_err={err:.4f}")
    # the orderings the paper's Figure 1a / Table 8 show:
    assert errs["gear_kivi2"] < errs["gear_l_kivi2"] < errs["kivi2"] < errs["per_token_q2"]
    assert errs["outlier_kivi2"] < errs["kivi2"]
    table10_h2o(key)
    spec = residual_spectrum(key)
    half = int(jnp.argmax(spec < 0.5))
    emit("fig2b_spectrum", 0.0,
         f"sigma_r/sigma_0 halves by r={half}; top8={['%.3f' % float(v) for v in spec[:8]]}")
    return errs


if __name__ == "__main__":
    run()
