"""Paper Fig 1b / Table 1 proxy: logit drift + generation agreement along
decode steps, on a small model briefly trained on structured synthetic data.

The paper's core qualitative claim: plain low-bit quantization compounds
approximation error across autoregressive steps and diverges from the FP16
trajectory; GEAR stays near-lossless.  We measure (a) max |Δlogit| vs FP16
per decode step, (b) token-level agreement of greedy generations.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import smoke_config
from repro.core.policy import FP16, named_policy
from repro.data.synthetic import DataConfig
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.train.loop import train_loop
from repro.train.state import RunConfig
import tempfile


def trained_small_model(steps: int = 40):
    cfg = dataclasses.replace(smoke_config("llama2-7b"), vocab_size=256)
    model = build_model(cfg)
    run = RunConfig(total_steps=steps, warmup_steps=5, microbatches=1, remat=False,
                    zero1=False, ckpt_dir=tempfile.mkdtemp(), ckpt_every=0,
                    log_every=10**9)
    dc = DataConfig(seed=3, vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    state = train_loop(model, jax.make_mesh((1, 1), ("data", "model")), run, dc,
                       log_fn=lambda *_: None)
    return cfg, model, jax.device_get(state.params)


def drift_curves(cfg, model, params, policies: dict, gen: int = 24, prompt: int = 40):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (4, prompt), 0,
                                          cfg.vocab_size)}
    base_tokens, base_logits = _rollout(cfg, model, params, batch, FP16, gen)
    out = {}
    for name, pol in policies.items():
        toks, logits = _rollout(cfg, model, params, batch, pol, gen)
        drift = jnp.abs(logits - base_logits).max(axis=(0, 2))     # per step
        agree = (toks == base_tokens).mean()
        out[name] = {"drift": drift, "agreement": float(agree)}
    return out


def _rollout(cfg, model, params, batch, policy, gen):
    eng = Engine(model, params, EngineConfig(batch=batch["tokens"].shape[0],
                                             capacity=128, policy=policy))
    logits, caches = eng.prefill(batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    toks, logit_list = [tok], [logits[:, -1]]
    pos = batch["tokens"].shape[1]
    for t in range(gen - 1):
        logits, caches = eng.decode({"tokens": tok[:, None]}, caches, pos + t)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        toks.append(tok)
        logit_list.append(logits[:, -1])
    return jnp.stack(toks, 1), jnp.stack(logit_list, 1)  # [B,T], [B,T,V]


def run():
    cfg, model, params = trained_small_model()
    nb16 = lambda n: dataclasses.replace(named_policy(n), buffer_size=16,
                                         group=min(16, named_policy(n).group))
    policies = {
        "per_token_q2": nb16("per_token_q2"),
        "kivi2": nb16("kivi2"),
        "gear_l_kivi2": nb16("gear_l_kivi2"),
        "gear_kivi2": nb16("gear_kivi2"),
        "gear_kcvt4": nb16("gear_kcvt4"),
    }
    res = drift_curves(cfg, model, params, policies)
    for name, r in res.items():
        d = r["drift"]
        emit(f"fig1b_drift/{name}", 0.0,
             f"agree={r['agreement']:.2f} drift_first={float(d[0]):.3f} "
             f"drift_last={float(d[-1]):.3f}")
    # GEAR tracks FP16 better than its own quant backbone
    assert res["gear_kivi2"]["agreement"] >= res["kivi2"]["agreement"]
    return res


if __name__ == "__main__":
    run()
